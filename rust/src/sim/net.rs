//! `SimNet`: a simulated network + client fleet behind the PR-3 reactor
//! interface.
//!
//! `SimNet` implements [`Reactor`], so the *production* event loop
//! ([`crate::coordinator::transport::reactor::drive`]) — or the
//! invariant-checking loop in [`super::harness`] — drives the engine
//! over it unchanged. The difference from `ChannelReactor`/`EpollReactor`
//! is that `poll` never sleeps: the reactor's clock is a [`SimClock`]
//! that jumps to the timestamp of the next scheduled event, so thousands
//! of multi-round federations run per wall-second.
//!
//! Every message's fate — deliver after latency, drop, duplicate,
//! delay, partition-block — comes from the [`FaultSchedule`]; client
//! compute happens inline (virtual-instant) when a delivery event pops,
//! via the [`SimPeer`] registered for the client. Crashes, late joins
//! and link flaps are schedule events too: a crash or flap surfaces to
//! the engine as the `Disconnected` it would see from a TCP reset, a
//! join or redial as a fresh `Connected` + `Hello`.
//!
//! Endpoints are connections, not clients: a founding member's first
//! connection gets endpoint id == client id (so flap-free worlds are
//! bitwise identical to the pre-reconnect sim), and every redial after
//! a [`Fault::Disconnect`] allocates a fresh endpoint id. Messages
//! in flight on either leg when the link drops are lost — a delivery
//! whose endpoint is stale (or whose link is down) by pop time never
//! arrives, exactly like bytes buffered in a reset TCP connection.

use std::collections::VecDeque;
use std::time::Duration;

use crate::bail;
use crate::error::Result;

use crate::coordinator::engine::EndpointId;
use crate::coordinator::transport::reactor::{IoEvent, Reactor};

use super::clock::{EventQueue, SimClock};
use super::schedule::{Dir, Fault, FaultSchedule};

/// A sans-I/O client: consumes protocol bytes, produces protocol bytes.
/// Implementations must mirror the real worker loop so a simulated run
/// is bitwise-comparable to a threaded in-proc run.
pub trait SimPeer {
    /// Messages the peer emits when it comes online (its `Hello`).
    fn on_start(&mut self) -> Vec<Vec<u8>>;

    /// Deliver one server→client message; returns the replies.
    fn on_message(&mut self, bytes: &[u8]) -> Vec<Vec<u8>>;

    /// Messages the peer emits when it redials after a link flap. The
    /// default re-runs `on_start`; a session-aware peer emits a
    /// token-bearing resume `Hello` instead.
    fn on_reconnect(&mut self) -> Vec<Vec<u8>> {
        self.on_start()
    }
}

enum NetEvent {
    /// client→server payload; `ep` is the connection it was written to
    DeliverToEngine { client: usize, ep: EndpointId, bytes: Vec<u8> },
    /// server→client payload; `ep` is the connection it was written to
    DeliverToPeer { client: usize, ep: EndpointId, bytes: Vec<u8> },
    Crash { client: usize },
    Join { client: usize },
    LinkDown { client: usize },
    Reconnect { client: usize },
}

/// Virtual-time reactor over a fleet of [`SimPeer`]s and one
/// [`FaultSchedule`].
pub struct SimNet {
    clock: SimClock,
    queue: EventQueue<NetEvent>,
    schedule: FaultSchedule,
    /// indexed by client id
    peers: Vec<Option<Box<dyn SimPeer>>>,
    /// false once the client process died (crash fault)
    alive: Vec<bool>,
    /// the client's current connection (stale eps identify lost traffic)
    ep_of: Vec<EndpointId>,
    /// false while the client's link is flapped down
    link_up: Vec<bool>,
    /// endpoint → owning client (grows as redials allocate endpoints)
    client_of: Vec<usize>,
    /// true once the engine closed its side of the endpoint (by ep)
    engine_closed: Vec<bool>,
    /// true once the engine saw the client's process death (by client)
    crash_notified: Vec<bool>,
    /// per-(dir, client) message counters — the `nth` of fate lookups
    sent_down: Vec<usize>,
    sent_up: Vec<usize>,
    pending: VecDeque<IoEvent>,
    /// faults that actually changed the run (empty ⇒ the bitwise
    /// invariant against the fault-free reference applies)
    materialized: Vec<String>,
    /// messages a `Delay` fault held (straggler/reorder ledger; delays
    /// are deliberately not `materialized` — see the bitwise invariant)
    delayed: usize,
}

impl SimNet {
    pub fn new(schedule: FaultSchedule, peers: Vec<Box<dyn SimPeer>>) -> Self {
        let n = peers.len();
        assert_eq!(n, schedule.clients, "schedule sized for a different fleet");
        let mut net = SimNet {
            clock: SimClock::new(),
            queue: EventQueue::new(),
            schedule,
            peers: peers.into_iter().map(Some).collect(),
            alive: vec![true; n],
            ep_of: (0..n).collect(),
            link_up: vec![true; n],
            client_of: (0..n).collect(),
            engine_closed: vec![false; n],
            crash_notified: vec![false; n],
            sent_down: vec![0; n],
            sent_up: vec![0; n],
            pending: VecDeque::new(),
            materialized: Vec::new(),
            delayed: 0,
        };
        for f in &net.schedule.faults {
            if let Fault::Disconnect { client, at_ms, reconnect_after_ms } = *f {
                net.queue.push_at(Duration::from_millis(at_ms), NetEvent::LinkDown { client });
                net.queue.push_at(
                    Duration::from_millis(at_ms + reconnect_after_ms),
                    NetEvent::Reconnect { client },
                );
            }
        }
        for client in 0..n {
            if let Some(at) = net.schedule.crash_time(client) {
                net.queue.push_at(at, NetEvent::Crash { client });
            }
            match net.schedule.join_time(client) {
                Some(at) => net.queue.push_at(at, NetEvent::Join { client }),
                None => net.start_peer(client),
            }
        }
        net
    }

    /// Faults that materialized so far (human-readable, in event order).
    pub fn materialized(&self) -> &[String] {
        &self.materialized
    }

    /// Messages held by a `Delay` fault so far.
    pub fn delayed(&self) -> usize {
        self.delayed
    }

    /// Announce the peer to the engine and put its Hello on the wire.
    fn start_peer(&mut self, client: usize) {
        if !self.alive[client] {
            return;
        }
        self.pending.push_back(IoEvent::Connected(self.ep_of[client]));
        let msgs = match self.peers[client].as_mut() {
            Some(peer) => peer.on_start(),
            None => return,
        };
        for m in msgs {
            self.send_up(client, m);
        }
    }

    /// One client→server message enters the world.
    fn send_up(&mut self, client: usize, bytes: Vec<u8>) {
        if !self.alive[client] || !self.link_up[client] {
            return;
        }
        let ep = self.ep_of[client];
        let nth = self.sent_up[client];
        self.sent_up[client] += 1;
        let now = self.clock.now();
        if self.schedule.crash_before_send(client, nth) {
            // the client dies instead of replying; the engine notices
            // one link-latency later, like a TCP reset would surface
            self.alive[client] = false;
            self.materialized
                .push(format!("client {client} crashed before sending msg {nth} at {now:?}"));
            let notice = now + self.schedule.base_latency(Dir::Up, client, nth);
            self.queue.push_at(notice, NetEvent::Crash { client });
            return;
        }
        if self.schedule.partitioned(client, now) {
            self.materialized
                .push(format!("partition ate up msg {nth} of client {client} at {now:?}"));
            return;
        }
        if self.schedule.is_delayed(Dir::Up, client, nth) {
            self.delayed += 1;
        }
        let fates = self.schedule.deliveries(Dir::Up, client, nth);
        if fates.is_empty() {
            self.materialized.push(format!("dropped up msg {nth} of client {client} at {now:?}"));
        } else if fates.len() > 1 {
            self.materialized
                .push(format!("duplicated up msg {nth} of client {client} at {now:?}"));
        }
        for latency in fates {
            self.queue.push_at(
                now + latency,
                NetEvent::DeliverToEngine { client, ep, bytes: bytes.clone() },
            );
        }
    }

    /// Is this delivery's connection still the client's live one?
    fn link_current(&self, client: usize, ep: EndpointId) -> bool {
        self.link_up[client] && self.ep_of[client] == ep
    }

    fn process(&mut self, event: NetEvent) {
        match event {
            NetEvent::DeliverToEngine { client, ep, bytes } => {
                // drop if the engine stopped reading (Close), already saw
                // the endpoint's reset, or the connection the bytes were
                // in flight on is gone: TCP never delivers stream data
                // after the disconnect surfaced
                if !self.engine_closed[ep]
                    && !self.crash_notified[client]
                    && self.link_current(client, ep)
                {
                    self.pending.push_back(IoEvent::Message(ep, bytes));
                }
            }
            NetEvent::DeliverToPeer { client, ep, bytes } => {
                if !self.alive[client] || !self.link_current(client, ep) {
                    return;
                }
                // take the peer out so replies can re-borrow the net
                let Some(mut peer) = self.peers[client].take() else { return };
                let replies = peer.on_message(&bytes);
                self.peers[client] = Some(peer);
                for r in replies {
                    self.send_up(client, r);
                }
            }
            NetEvent::Crash { client } => {
                self.alive[client] = false;
                if !self.crash_notified[client] {
                    self.crash_notified[client] = true;
                    self.materialized
                        .push(format!("client {client} dead at {:?}", self.clock.now()));
                    // the engine gets at most one Disconnected per lost
                    // connection: if the link already flapped down, the
                    // reset was surfaced then and the grace window is
                    // already running (it expires into departure)
                    let ep = self.ep_of[client];
                    if self.link_up[client] && !self.engine_closed[ep] {
                        self.pending.push_back(IoEvent::Disconnected(ep));
                    }
                }
            }
            NetEvent::Join { client } => {
                self.materialized.push(format!("client {client} joined at {:?}", self.clock.now()));
                self.start_peer(client);
            }
            NetEvent::LinkDown { client } => {
                if !self.alive[client] || !self.link_up[client] {
                    return;
                }
                self.link_up[client] = false;
                self.materialized
                    .push(format!("link of client {client} dropped at {:?}", self.clock.now()));
                let ep = self.ep_of[client];
                if !self.engine_closed[ep] {
                    self.pending.push_back(IoEvent::Disconnected(ep));
                }
            }
            NetEvent::Reconnect { client } => {
                if !self.alive[client] || self.link_up[client] {
                    return;
                }
                // redial: a fresh connection, so a fresh endpoint id —
                // anything still in flight on the old one is lost
                let ep = self.client_of.len();
                self.client_of.push(client);
                self.engine_closed.push(false);
                self.ep_of[client] = ep;
                self.link_up[client] = true;
                self.materialized.push(format!(
                    "client {client} redialed as endpoint {ep} at {:?}",
                    self.clock.now()
                ));
                self.pending.push_back(IoEvent::Connected(ep));
                let msgs = match self.peers[client].as_mut() {
                    Some(peer) => peer.on_reconnect(),
                    None => return,
                };
                for m in msgs {
                    self.send_up(client, m);
                }
            }
        }
    }
}

impl Reactor for SimNet {
    /// Advance virtual time, running the world, until an engine-facing
    /// event is due or `timeout` virtual time has passed. Never sleeps.
    fn poll(&mut self, timeout: Option<Duration>) -> Result<IoEvent> {
        let deadline = timeout.map(|t| self.clock.now() + t);
        loop {
            if let Some(e) = self.pending.pop_front() {
                return Ok(e);
            }
            match self.queue.next_time() {
                Some(t) if deadline.is_none_or(|d| t <= d) => {
                    self.clock.advance_to(t);
                    let (_, event) = self.queue.pop().expect("peeked event vanished");
                    self.process(event);
                }
                _ => {
                    // nothing due inside the window: burn the wait
                    // instantly (an unbounded poll with an empty queue
                    // would spin — report the idle tick instead)
                    if let Some(d) = deadline {
                        self.clock.advance_to(d);
                    }
                    return Ok(IoEvent::Tick);
                }
            }
        }
    }

    /// One server→client message enters the world.
    fn send(&mut self, ep: EndpointId, msg: &[u8]) -> Result<()> {
        let Some(&client) = self.client_of.get(ep) else {
            bail!("endpoint {ep} does not exist");
        };
        if self.engine_closed[ep] {
            bail!("endpoint {ep} is closed");
        }
        let nth = self.sent_down[client];
        self.sent_down[client] += 1;
        let now = self.clock.now();
        if !self.alive[client] || !self.link_current(client, ep) {
            // written into the void between the reset and the engine
            // noticing — in-flight loss, not an error
            return Ok(());
        }
        if self.schedule.partitioned(client, now) {
            self.materialized
                .push(format!("partition ate down msg {nth} to client {client} at {now:?}"));
            return Ok(());
        }
        if self.schedule.is_delayed(Dir::Down, client, nth) {
            self.delayed += 1;
        }
        let fates = self.schedule.deliveries(Dir::Down, client, nth);
        if fates.is_empty() {
            self.materialized
                .push(format!("dropped down msg {nth} to client {client} at {now:?}"));
        } else if fates.len() > 1 {
            self.materialized
                .push(format!("duplicated down msg {nth} to client {client} at {now:?}"));
        }
        for latency in fates {
            self.queue.push_at(
                now + latency,
                NetEvent::DeliverToPeer { client, ep, bytes: msg.to_vec() },
            );
        }
        Ok(())
    }

    fn close(&mut self, ep: EndpointId) {
        if let Some(slot) = self.engine_closed.get_mut(ep) {
            *slot = true;
        }
    }

    fn now(&self) -> Duration {
        self.clock.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo peer: replies `reply` to every delivery, `hello` on start.
    struct Echo {
        hello: Vec<u8>,
        reply: Vec<u8>,
        seen: usize,
    }

    impl SimPeer for Echo {
        fn on_start(&mut self) -> Vec<Vec<u8>> {
            vec![self.hello.clone()]
        }

        fn on_message(&mut self, _bytes: &[u8]) -> Vec<Vec<u8>> {
            self.seen += 1;
            vec![self.reply.clone()]
        }
    }

    fn echo_fleet(n: usize) -> Vec<Box<dyn SimPeer>> {
        (0..n)
            .map(|i| {
                Box::new(Echo { hello: vec![i as u8], reply: vec![100 + i as u8], seen: 0 })
                    as Box<dyn SimPeer>
            })
            .collect()
    }

    #[test]
    fn virtual_time_advances_without_sleeping() {
        let schedule = FaultSchedule::fault_free(3, 2, 4);
        let mut net = SimNet::new(schedule, echo_fleet(2));
        // both peers announce + their hellos arrive within base latency
        let wall = std::time::Instant::now();
        let mut connected = 0;
        let mut hellos = 0;
        for _ in 0..8 {
            match net.poll(Some(Duration::from_secs(3600))).unwrap() {
                IoEvent::Connected(_) => connected += 1,
                IoEvent::Message(ep, m) => {
                    assert_eq!(m, vec![ep as u8]);
                    hellos += 1;
                }
                IoEvent::Tick => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(connected, 2);
        assert_eq!(hellos, 2);
        // a full simulated hour of idle polling costs ~no wall time
        assert!(matches!(net.poll(Some(Duration::from_secs(3600))).unwrap(), IoEvent::Tick));
        assert!(net.now() >= Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(5), "sim slept on the wall clock");
    }

    #[test]
    fn send_round_trips_through_a_peer() {
        let schedule = FaultSchedule::fault_free(5, 1, 4);
        let mut net = SimNet::new(schedule, echo_fleet(1));
        // drain hello traffic
        while !matches!(net.poll(Some(Duration::from_millis(50))).unwrap(), IoEvent::Tick) {}
        net.send(0, b"ping").unwrap();
        match net.poll(Some(Duration::from_millis(50))).unwrap() {
            IoEvent::Message(0, m) => assert_eq!(m, vec![100]),
            other => panic!("expected echo reply, got {other:?}"),
        }
    }

    #[test]
    fn crash_surfaces_as_disconnect_and_silences_the_peer() {
        let mut schedule = FaultSchedule::fault_free(7, 2, 4);
        schedule.faults.push(crate::sim::Fault::CrashAt { client: 1, at_ms: 10 });
        let mut net = SimNet::new(schedule, echo_fleet(2));
        let mut disconnected = None;
        for _ in 0..16 {
            match net.poll(Some(Duration::from_millis(100))).unwrap() {
                IoEvent::Disconnected(ep) => {
                    disconnected = Some(ep);
                    break;
                }
                IoEvent::Tick => break,
                _ => {}
            }
        }
        assert_eq!(disconnected, Some(1));
        // sends to the dead peer vanish quietly; the live one still echoes
        net.send(1, b"x").unwrap();
        net.send(0, b"y").unwrap();
        let mut echoed = false;
        for _ in 0..8 {
            match net.poll(Some(Duration::from_millis(100))).unwrap() {
                IoEvent::Message(0, m) => {
                    assert_eq!(m, vec![100]);
                    echoed = true;
                    break;
                }
                IoEvent::Tick => break,
                _ => {}
            }
        }
        assert!(echoed);
        assert!(!net.materialized().is_empty());
    }

    #[test]
    fn flap_rebinds_the_client_to_a_fresh_endpoint() {
        let mut schedule = FaultSchedule::fault_free(11, 2, 4);
        schedule
            .faults
            .push(Fault::Disconnect { client: 0, at_ms: 20, reconnect_after_ms: 5 });
        let mut net = SimNet::new(schedule, echo_fleet(2));
        // drain startup traffic
        while !matches!(net.poll(Some(Duration::from_millis(15))).unwrap(), IoEvent::Tick) {}
        let mut saw_disconnect = false;
        let mut new_ep = None;
        let mut rehello = None;
        for _ in 0..16 {
            match net.poll(Some(Duration::from_millis(100))).unwrap() {
                IoEvent::Disconnected(0) => saw_disconnect = true,
                IoEvent::Connected(ep) => new_ep = Some(ep),
                IoEvent::Message(ep, m) => rehello = Some((ep, m)),
                IoEvent::Tick => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert!(saw_disconnect, "flap surfaces as a TCP reset");
        // the redial shows up on a brand-new endpoint with a Hello
        assert_eq!(new_ep, Some(2), "redial allocates the next endpoint id");
        assert_eq!(rehello, Some((2, vec![0u8])), "peer re-announced on the new endpoint");
        // the old endpoint is stale: sends to it vanish, not error
        net.send(0, b"stale").unwrap();
        // the new endpoint round-trips
        net.send(2, b"ping").unwrap();
        let mut echoed = false;
        for _ in 0..8 {
            match net.poll(Some(Duration::from_millis(100))).unwrap() {
                IoEvent::Message(2, m) => {
                    assert_eq!(m, vec![100]);
                    echoed = true;
                    break;
                }
                IoEvent::Tick => break,
                _ => {}
            }
        }
        assert!(echoed);
    }

    #[test]
    fn closed_endpoint_rejects_sends() {
        let schedule = FaultSchedule::fault_free(9, 1, 4);
        let mut net = SimNet::new(schedule, echo_fleet(1));
        net.close(0);
        assert!(net.send(0, b"late").is_err());
        assert!(net.send(7, b"bogus").is_err());
    }
}
