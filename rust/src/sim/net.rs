//! `SimNet`: a simulated network + client fleet behind the PR-3 reactor
//! interface.
//!
//! `SimNet` implements [`Reactor`], so the *production* event loop
//! ([`crate::coordinator::transport::reactor::drive`]) — or the
//! invariant-checking loop in [`super::harness`] — drives the engine
//! over it unchanged. The difference from `ChannelReactor`/`EpollReactor`
//! is that `poll` never sleeps: the reactor's clock is a [`SimClock`]
//! that jumps to the timestamp of the next scheduled event, so thousands
//! of multi-round federations run per wall-second.
//!
//! Every message's fate — deliver after latency, drop, duplicate,
//! delay, partition-block — comes from the [`FaultSchedule`]; client
//! compute happens inline (virtual-instant) when a delivery event pops,
//! via the [`SimPeer`] registered for the endpoint. Crashes and late
//! joins are schedule events too: a crash surfaces to the engine as the
//! `Disconnected` it would see from a TCP reset, a join as a fresh
//! `Connected` + `Hello`.
//!
//! Endpoint ids equal client ids (the sim never reconnects an endpoint),
//! which keeps fault-schedule lookups and engine bindings aligned.

use std::collections::VecDeque;
use std::time::Duration;

use crate::bail;
use crate::error::Result;

use crate::coordinator::engine::EndpointId;
use crate::coordinator::transport::reactor::{IoEvent, Reactor};

use super::clock::{EventQueue, SimClock};
use super::schedule::{Dir, FaultSchedule};

/// A sans-I/O client: consumes protocol bytes, produces protocol bytes.
/// Implementations must mirror the real worker loop so a simulated run
/// is bitwise-comparable to a threaded in-proc run.
pub trait SimPeer {
    /// Messages the peer emits when it comes online (its `Hello`).
    fn on_start(&mut self) -> Vec<Vec<u8>>;

    /// Deliver one server→client message; returns the replies.
    fn on_message(&mut self, bytes: &[u8]) -> Vec<Vec<u8>>;
}

enum NetEvent {
    DeliverToEngine { ep: EndpointId, bytes: Vec<u8> },
    DeliverToPeer { ep: EndpointId, bytes: Vec<u8> },
    Crash { ep: EndpointId },
    Join { ep: EndpointId },
}

/// Virtual-time reactor over a fleet of [`SimPeer`]s and one
/// [`FaultSchedule`].
pub struct SimNet {
    clock: SimClock,
    queue: EventQueue<NetEvent>,
    schedule: FaultSchedule,
    peers: Vec<Option<Box<dyn SimPeer>>>,
    /// false once the client process died (crash fault)
    alive: Vec<bool>,
    /// true once the engine closed its side of the endpoint
    engine_closed: Vec<bool>,
    crash_notified: Vec<bool>,
    /// per-(dir, client) message counters — the `nth` of fate lookups
    sent_down: Vec<usize>,
    sent_up: Vec<usize>,
    pending: VecDeque<IoEvent>,
    /// faults that actually changed the run (empty ⇒ the bitwise
    /// invariant against the fault-free reference applies)
    materialized: Vec<String>,
    /// messages a `Delay` fault held (straggler/reorder ledger; delays
    /// are deliberately not `materialized` — see the bitwise invariant)
    delayed: usize,
}

impl SimNet {
    pub fn new(schedule: FaultSchedule, peers: Vec<Box<dyn SimPeer>>) -> Self {
        let n = peers.len();
        assert_eq!(n, schedule.clients, "schedule sized for a different fleet");
        let mut net = SimNet {
            clock: SimClock::new(),
            queue: EventQueue::new(),
            schedule,
            peers: peers.into_iter().map(Some).collect(),
            alive: vec![true; n],
            engine_closed: vec![false; n],
            crash_notified: vec![false; n],
            sent_down: vec![0; n],
            sent_up: vec![0; n],
            pending: VecDeque::new(),
            materialized: Vec::new(),
            delayed: 0,
        };
        for ep in 0..n {
            if let Some(at) = net.schedule.crash_time(ep) {
                net.queue.push_at(at, NetEvent::Crash { ep });
            }
            match net.schedule.join_time(ep) {
                Some(at) => net.queue.push_at(at, NetEvent::Join { ep }),
                None => net.start_peer(ep),
            }
        }
        net
    }

    /// Faults that materialized so far (human-readable, in event order).
    pub fn materialized(&self) -> &[String] {
        &self.materialized
    }

    /// Messages held by a `Delay` fault so far.
    pub fn delayed(&self) -> usize {
        self.delayed
    }

    /// Announce the peer to the engine and put its Hello on the wire.
    fn start_peer(&mut self, ep: EndpointId) {
        if !self.alive[ep] {
            return;
        }
        self.pending.push_back(IoEvent::Connected(ep));
        let msgs = match self.peers[ep].as_mut() {
            Some(peer) => peer.on_start(),
            None => return,
        };
        for m in msgs {
            self.send_up(ep, m);
        }
    }

    /// One client→server message enters the world.
    fn send_up(&mut self, ep: EndpointId, bytes: Vec<u8>) {
        if !self.alive[ep] {
            return;
        }
        let nth = self.sent_up[ep];
        self.sent_up[ep] += 1;
        let now = self.clock.now();
        if self.schedule.crash_before_send(ep, nth) {
            // the client dies instead of replying; the engine notices
            // one link-latency later, like a TCP reset would surface
            self.alive[ep] = false;
            self.materialized
                .push(format!("client {ep} crashed before sending msg {nth} at {now:?}"));
            let notice = now + self.schedule.base_latency(Dir::Up, ep, nth);
            self.queue.push_at(notice, NetEvent::Crash { ep });
            return;
        }
        if self.schedule.partitioned(ep, now) {
            self.materialized.push(format!("partition ate up msg {nth} of client {ep} at {now:?}"));
            return;
        }
        if self.schedule.is_delayed(Dir::Up, ep, nth) {
            self.delayed += 1;
        }
        let fates = self.schedule.deliveries(Dir::Up, ep, nth);
        if fates.is_empty() {
            self.materialized.push(format!("dropped up msg {nth} of client {ep} at {now:?}"));
        } else if fates.len() > 1 {
            self.materialized.push(format!("duplicated up msg {nth} of client {ep} at {now:?}"));
        }
        for latency in fates {
            self.queue
                .push_at(now + latency, NetEvent::DeliverToEngine { ep, bytes: bytes.clone() });
        }
    }

    fn process(&mut self, event: NetEvent) {
        match event {
            NetEvent::DeliverToEngine { ep, bytes } => {
                // drop if the engine stopped reading (Close) or already
                // saw the endpoint's reset: TCP never delivers stream
                // data after the disconnect surfaced
                if !self.engine_closed[ep] && !self.crash_notified[ep] {
                    self.pending.push_back(IoEvent::Message(ep, bytes));
                }
            }
            NetEvent::DeliverToPeer { ep, bytes } => {
                if !self.alive[ep] {
                    return;
                }
                // take the peer out so replies can re-borrow the net
                let Some(mut peer) = self.peers[ep].take() else { return };
                let replies = peer.on_message(&bytes);
                self.peers[ep] = Some(peer);
                for r in replies {
                    self.send_up(ep, r);
                }
            }
            NetEvent::Crash { ep } => {
                self.alive[ep] = false;
                if !self.crash_notified[ep] {
                    self.crash_notified[ep] = true;
                    self.materialized.push(format!("client {ep} dead at {:?}", self.clock.now()));
                    if !self.engine_closed[ep] {
                        self.pending.push_back(IoEvent::Disconnected(ep));
                    }
                }
            }
            NetEvent::Join { ep } => {
                self.materialized.push(format!("client {ep} joined at {:?}", self.clock.now()));
                self.start_peer(ep);
            }
        }
    }
}

impl Reactor for SimNet {
    /// Advance virtual time, running the world, until an engine-facing
    /// event is due or `timeout` virtual time has passed. Never sleeps.
    fn poll(&mut self, timeout: Option<Duration>) -> Result<IoEvent> {
        let deadline = timeout.map(|t| self.clock.now() + t);
        loop {
            if let Some(e) = self.pending.pop_front() {
                return Ok(e);
            }
            match self.queue.next_time() {
                Some(t) if deadline.is_none_or(|d| t <= d) => {
                    self.clock.advance_to(t);
                    let (_, event) = self.queue.pop().expect("peeked event vanished");
                    self.process(event);
                }
                _ => {
                    // nothing due inside the window: burn the wait
                    // instantly (an unbounded poll with an empty queue
                    // would spin — report the idle tick instead)
                    if let Some(d) = deadline {
                        self.clock.advance_to(d);
                    }
                    return Ok(IoEvent::Tick);
                }
            }
        }
    }

    /// One server→client message enters the world.
    fn send(&mut self, ep: EndpointId, msg: &[u8]) -> Result<()> {
        if ep >= self.peers.len() || self.engine_closed[ep] {
            bail!("endpoint {ep} is closed");
        }
        let nth = self.sent_down[ep];
        self.sent_down[ep] += 1;
        let now = self.clock.now();
        if !self.alive[ep] {
            // written into the void between the crash and the engine
            // noticing — in-flight loss, not an error
            return Ok(());
        }
        if self.schedule.partitioned(ep, now) {
            self.materialized
                .push(format!("partition ate down msg {nth} to client {ep} at {now:?}"));
            return Ok(());
        }
        if self.schedule.is_delayed(Dir::Down, ep, nth) {
            self.delayed += 1;
        }
        let fates = self.schedule.deliveries(Dir::Down, ep, nth);
        if fates.is_empty() {
            self.materialized.push(format!("dropped down msg {nth} to client {ep} at {now:?}"));
        } else if fates.len() > 1 {
            self.materialized.push(format!("duplicated down msg {nth} to client {ep} at {now:?}"));
        }
        for latency in fates {
            self.queue
                .push_at(now + latency, NetEvent::DeliverToPeer { ep, bytes: msg.to_vec() });
        }
        Ok(())
    }

    fn close(&mut self, ep: EndpointId) {
        if ep < self.engine_closed.len() {
            self.engine_closed[ep] = true;
        }
    }

    fn now(&self) -> Duration {
        self.clock.now()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Echo peer: replies `reply` to every delivery, `hello` on start.
    struct Echo {
        hello: Vec<u8>,
        reply: Vec<u8>,
        seen: usize,
    }

    impl SimPeer for Echo {
        fn on_start(&mut self) -> Vec<Vec<u8>> {
            vec![self.hello.clone()]
        }

        fn on_message(&mut self, _bytes: &[u8]) -> Vec<Vec<u8>> {
            self.seen += 1;
            vec![self.reply.clone()]
        }
    }

    fn echo_fleet(n: usize) -> Vec<Box<dyn SimPeer>> {
        (0..n)
            .map(|i| {
                Box::new(Echo { hello: vec![i as u8], reply: vec![100 + i as u8], seen: 0 })
                    as Box<dyn SimPeer>
            })
            .collect()
    }

    #[test]
    fn virtual_time_advances_without_sleeping() {
        let schedule = FaultSchedule::fault_free(3, 2, 4);
        let mut net = SimNet::new(schedule, echo_fleet(2));
        // both peers announce + their hellos arrive within base latency
        let wall = std::time::Instant::now();
        let mut connected = 0;
        let mut hellos = 0;
        for _ in 0..8 {
            match net.poll(Some(Duration::from_secs(3600))).unwrap() {
                IoEvent::Connected(_) => connected += 1,
                IoEvent::Message(ep, m) => {
                    assert_eq!(m, vec![ep as u8]);
                    hellos += 1;
                }
                IoEvent::Tick => break,
                other => panic!("unexpected {other:?}"),
            }
        }
        assert_eq!(connected, 2);
        assert_eq!(hellos, 2);
        // a full simulated hour of idle polling costs ~no wall time
        assert!(matches!(net.poll(Some(Duration::from_secs(3600))).unwrap(), IoEvent::Tick));
        assert!(net.now() >= Duration::from_secs(3600));
        assert!(wall.elapsed() < Duration::from_secs(5), "sim slept on the wall clock");
    }

    #[test]
    fn send_round_trips_through_a_peer() {
        let schedule = FaultSchedule::fault_free(5, 1, 4);
        let mut net = SimNet::new(schedule, echo_fleet(1));
        // drain hello traffic
        while !matches!(net.poll(Some(Duration::from_millis(50))).unwrap(), IoEvent::Tick) {}
        net.send(0, b"ping").unwrap();
        match net.poll(Some(Duration::from_millis(50))).unwrap() {
            IoEvent::Message(0, m) => assert_eq!(m, vec![100]),
            other => panic!("expected echo reply, got {other:?}"),
        }
    }

    #[test]
    fn crash_surfaces_as_disconnect_and_silences_the_peer() {
        let mut schedule = FaultSchedule::fault_free(7, 2, 4);
        schedule.faults.push(crate::sim::Fault::CrashAt { client: 1, at_ms: 10 });
        let mut net = SimNet::new(schedule, echo_fleet(2));
        let mut disconnected = None;
        for _ in 0..16 {
            match net.poll(Some(Duration::from_millis(100))).unwrap() {
                IoEvent::Disconnected(ep) => {
                    disconnected = Some(ep);
                    break;
                }
                IoEvent::Tick => break,
                _ => {}
            }
        }
        assert_eq!(disconnected, Some(1));
        // sends to the dead peer vanish quietly; the live one still echoes
        net.send(1, b"x").unwrap();
        net.send(0, b"y").unwrap();
        let mut echoed = false;
        for _ in 0..8 {
            match net.poll(Some(Duration::from_millis(100))).unwrap() {
                IoEvent::Message(0, m) => {
                    assert_eq!(m, vec![100]);
                    echoed = true;
                    break;
                }
                IoEvent::Tick => break,
                _ => {}
            }
        }
        assert!(echoed);
        assert!(!net.materialized().is_empty());
    }

    #[test]
    fn closed_endpoint_rejects_sends() {
        let schedule = FaultSchedule::fault_free(9, 1, 4);
        let mut net = SimNet::new(schedule, echo_fleet(1));
        net.close(0);
        assert!(net.send(0, b"late").is_err());
        assert!(net.send(7, b"bogus").is_err());
    }
}
