//! Tree topologies for the hierarchical aggregation tier, and the
//! simulated worlds that check them against the star baseline.
//!
//! Three layers live here:
//!
//! - [`TreeTopology`] — pure shape math: given a leaf fleet E and a
//!   power-of-two arity, the relay spans per level, the root's fan-in,
//!   and the per-level straggler deadlines (each strictly below its
//!   parent's, so a child level's cut always fires first). Shared by
//!   the `simulate --topology tree` CLI, the `comm_scaling` bench and
//!   the tree fuzz tests, so all three agree on what "arity 8 over
//!   10 000 leaves" means.
//! - [`RelayNode`] — a full relay (relay-mode [`RoundEngine`] plus
//!   [`RelaySession`]) behind the [`SimPeer`] interface. Its subtree is
//!   pumped *inline* (virtual-instant) on a private monotone clock:
//!   when the subtree quiesces while the engine is still collecting, a
//!   child has gone silent and the clock jumps past the level deadline
//!   to fire the subtree's own straggler cut deterministically. Nodes
//!   nest, so multi-level trees are just relays whose children are
//!   relays.
//! - [`TreeSim`] — one problem, one leaf fleet, two worlds: `run_star`
//!   drives all E leaves directly under the root, `run_tree` groups the
//!   same leaves under relays per the topology. Because the engine's
//!   reduction associates over power-of-two slot spans, the two runs
//!   must agree on the final factor *bit for bit*; `check_tree_seed`
//!   fuzzes that identity under relay crash/flap schedules from
//!   [`FaultSchedule::draw_tree`], with the same greedy shrink the star
//!   harness uses.

use std::cell::OnceCell;
use std::collections::VecDeque;
use std::mem;
use std::ops::Range;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::bail;
use crate::error::Result;

use crate::algorithms::factor::FactorHyper;
use crate::coordinator::client::{ClientConfig, ClientSession, FaultPlan};
use crate::coordinator::compress::Compression;
use crate::coordinator::engine::{Action, RoundEngine};
use crate::coordinator::kernel::NativeKernel;
use crate::coordinator::protocol::{restamp_seq, ToClient};
use crate::coordinator::relay::RelaySession;
use crate::coordinator::server::{FaultPolicy, ServerConfig, ServerOutcome};
use crate::coordinator::transport::reactor::{IoEvent, Reactor};
use crate::rpca::partition::ColumnPartition;
use crate::rpca::problem::{ProblemSpec, RpcaProblem};
use crate::runtime::pool::ThreadPool;

use super::harness::{FuzzSummary, SimReport, Violation};
use super::net::{SimNet, SimPeer};
use super::schedule::{Fault, FaultSchedule};

/// Largest idle poll while deadlines are pending (virtual, free).
const MAX_IDLE_POLL: Duration = Duration::from_millis(100);

/// Terminate-or-fail budget for one simulated world.
const MAX_EVENTS: u64 = 1_000_000;

/// Ceiling on consecutive forced deadline jumps inside one relay pump —
/// each jump transitions the engine's phase, so a legal run needs at
/// most a handful; hitting the cap means the engine livelocked.
const MAX_FORCED_CUTS: usize = 64;

// ---------------------------------------------------------------------------
// shape math
// ---------------------------------------------------------------------------

/// Shape of one aggregation tree: `leaves` slots fanned under relays of
/// `arity` children each, `levels` relay tiers deep (0 = plain star).
///
/// Slots are grouped by aligned power-of-two blocks: the level-`l` relay
/// over slot block `b` spans `[b·arity^l, (b+1)·arity^l)`, which is
/// exactly a canonical node of the engine's span reduction — any
/// grouping the topology produces therefore reduces bitwise identically
/// to the ungrouped star fold.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TreeTopology {
    /// leaf fleet size E
    pub leaves: usize,
    /// children per relay (power of two ≥ 2)
    pub arity: usize,
    /// relay tiers between the leaves and the root (0 = star)
    pub levels: usize,
}

impl TreeTopology {
    /// Smallest tree of `arity`-wide relays whose root ingests at most
    /// `arity` connections for `leaves` slots.
    pub fn new(leaves: usize, arity: usize) -> Result<Self> {
        if leaves == 0 {
            bail!("tree topology needs at least one leaf");
        }
        if arity < 2 || !arity.is_power_of_two() {
            bail!("tree arity must be a power of two >= 2, got {arity}");
        }
        let mut levels = 0usize;
        let mut top = leaves;
        while top > arity {
            top = top.div_ceil(arity);
            levels += 1;
        }
        Ok(TreeTopology { leaves, arity, levels })
    }

    /// Slot span of a level-`level` relay (level 1 fronts leaves).
    pub fn span_at(&self, level: usize) -> usize {
        self.arity.pow(level as u32)
    }

    /// Slot span of the relays directly under the root.
    pub fn top_span(&self) -> usize {
        self.span_at(self.levels)
    }

    /// Connections the root actually serves (≤ arity by construction).
    pub fn top_count(&self) -> usize {
        self.leaves.div_ceil(self.top_span())
    }

    /// Relays at each level, bottom-up (empty for a star).
    pub fn relays_per_level(&self) -> Vec<usize> {
        (1..=self.levels).map(|l| self.leaves.div_ceil(self.span_at(l))).collect()
    }

    /// Total relay processes the tree needs.
    pub fn relay_count(&self) -> usize {
        self.relays_per_level().iter().sum()
    }

    /// Straggler deadline of a level-`level` relay, scaled down from the
    /// root's so the windows nest: a parent at level `l+1` always waits
    /// strictly longer than its children at level `l`, leaving one
    /// level-hop of slack for the forwarded partial to travel (see
    /// EXPERIMENTS.md — T_parent > T_child + 2·hop-latency must hold for
    /// a child-level cut to resolve before the parent's own deadline).
    pub fn level_timeout(&self, root_timeout: Duration, level: usize) -> Duration {
        let denom = (self.levels + 1) as u64;
        let micros = root_timeout.as_micros() as u64;
        Duration::from_micros((micros * level as u64 / denom).max(1_000))
    }
}

// ---------------------------------------------------------------------------
// peers: leaves, a mute wrapper, and the relay node
// ---------------------------------------------------------------------------

/// A worker leaf behind the [`SimPeer`] interface: the production
/// [`ClientSession`] over a [`NativeKernel`] (optionally on a shared
/// fixed-width pool, for the `--threads 1/2/4` determinism sweeps).
pub struct LeafPeer {
    session: ClientSession,
    kernel: NativeKernel,
}

impl LeafPeer {
    pub fn new(cfg: ClientConfig, pool: Option<Arc<ThreadPool>>) -> Self {
        let kernel = match pool {
            Some(p) => NativeKernel::with_pool(p),
            None => NativeKernel::new(),
        };
        LeafPeer { session: ClientSession::new(cfg), kernel }
    }
}

impl SimPeer for LeafPeer {
    fn on_start(&mut self) -> Vec<Vec<u8>> {
        vec![self.session.hello()]
    }

    fn on_message(&mut self, bytes: &[u8]) -> Vec<Vec<u8>> {
        let step = self.session.handle(bytes, &self.kernel).expect("leaf session failed");
        step.replies
    }
}

/// Wrapper that swallows a peer's replies to exactly one round's
/// broadcast — the deterministic "one leaf misses the deadline" world.
/// The inner session still computes (like a reply lost on the wire), so
/// wrapping the same leaf in both the star and the tree run keeps the
/// two worlds comparable: both reductions see the identical slot set.
pub struct MuteAtRound {
    inner: Box<dyn SimPeer>,
    round: u32,
}

impl MuteAtRound {
    pub fn new(inner: Box<dyn SimPeer>, round: u32) -> Self {
        MuteAtRound { inner, round }
    }
}

impl SimPeer for MuteAtRound {
    fn on_start(&mut self) -> Vec<Vec<u8>> {
        self.inner.on_start()
    }

    fn on_message(&mut self, bytes: &[u8]) -> Vec<Vec<u8>> {
        let replies = self.inner.on_message(bytes);
        if let Ok((_, ToClient::Round { round, .. })) = ToClient::decode_job(bytes) {
            if round == self.round {
                return Vec::new();
            }
        }
        replies
    }

    fn on_reconnect(&mut self) -> Vec<Vec<u8>> {
        self.inner.on_reconnect()
    }
}

/// A relay behind the [`SimPeer`] interface: downstream it owns a
/// relay-mode [`RoundEngine`] serving its children *inline* (child
/// compute is virtual-instant, like every [`SimPeer`]); upstream it is
/// one peer of the enclosing network, introduced by its
/// [`RelaySession`]'s span-stamped `Hello`.
///
/// The private clock only moves when the subtree stalls: if the pump
/// quiesces while the engine still waits on a child (a muted leaf, or a
/// nested relay whose own subtree was cut empty), the clock jumps past
/// the engine's next deadline and fires it — the same straggler cut the
/// process-world relay applies in real time, made deterministic.
pub struct RelayNode {
    engine: RoundEngine,
    session: RelaySession,
    children: Vec<Option<Box<dyn SimPeer>>>,
    /// engine closed its side of the child connection
    closed: Vec<bool>,
    /// private monotone clock (jumps only to fire deadlines)
    clock: Duration,
    started: bool,
}

impl RelayNode {
    /// `cfg` must be a [`crate::coordinator::server::JobMode::Relay`]
    /// config (see [`ServerConfig::relay`]); one child per subtree slot.
    pub fn new(cfg: ServerConfig, children: Vec<Box<dyn SimPeer>>) -> Self {
        assert!(!children.is_empty(), "a relay needs at least one child");
        let mut engine = RoundEngine::new();
        engine.add_job(0, cfg.clone(), children.len());
        let session = RelaySession::new(0, &cfg).expect("RelayNode requires a relay-mode config");
        let closed = vec![false; children.len()];
        RelayNode {
            engine,
            session,
            children: children.into_iter().map(Some).collect(),
            closed,
            clock: Duration::ZERO,
            started: false,
        }
    }

    /// Drain engine actions through the subtree until nothing moves,
    /// forcing the level deadline when a child went silent. Returns the
    /// upstream payloads produced (unstamped).
    fn pump(&mut self, pending: Vec<Action>) -> Vec<Vec<u8>> {
        let mut queue: VecDeque<Action> = pending.into();
        let mut ups = Vec::new();
        let mut forced = 0usize;
        loop {
            while let Some(action) = queue.pop_front() {
                match action {
                    Action::Send { ep, bytes } => {
                        if self.closed.get(ep).copied().unwrap_or(true) {
                            continue;
                        }
                        let Some(mut child) = self.children[ep].take() else { continue };
                        let replies = child.on_message(&bytes);
                        self.children[ep] = Some(child);
                        for reply in replies {
                            queue.extend(self.engine.handle_message(ep, &reply, self.clock));
                        }
                    }
                    Action::Broadcast { peers, body } => {
                        for (ep, seq) in peers {
                            if self.closed.get(ep).copied().unwrap_or(true) {
                                continue;
                            }
                            let mut bytes = body.as_ref().clone();
                            restamp_seq(&mut bytes, seq);
                            let Some(mut child) = self.children[ep].take() else { continue };
                            let replies = child.on_message(&bytes);
                            self.children[ep] = Some(child);
                            for reply in replies {
                                queue.extend(self.engine.handle_message(ep, &reply, self.clock));
                            }
                        }
                    }
                    Action::Close { ep } => {
                        if let Some(slot) = self.closed.get_mut(ep) {
                            *slot = true;
                        }
                    }
                    Action::JobDone { .. } => {}
                    Action::Upstream { bytes, .. } => ups.push(bytes),
                }
            }
            // quiescent: if the engine still waits on a silent child,
            // jump the clock past the level deadline and fire the
            // subtree's own straggler cut
            let waiting =
                matches!(self.engine.phase_of(0), Some("collecting") | Some("finishing"));
            match self.engine.next_deadline() {
                Some(d) if waiting && forced < MAX_FORCED_CUTS => {
                    forced += 1;
                    self.clock = self.clock.max(d + Duration::from_millis(1));
                    queue.extend(self.engine.poll_deadline(self.clock));
                }
                _ => break,
            }
        }
        ups
    }
}

impl SimPeer for RelayNode {
    /// First start: run the downstream handshake to completion (every
    /// child's `Hello`, pumped inline), then introduce the whole span
    /// upstream. Redials reuse this path — `RelaySession::hello`
    /// carries the token once a `Welcome` landed, so the default
    /// `on_reconnect` resumes instead of re-introducing.
    fn on_start(&mut self) -> Vec<Vec<u8>> {
        if !self.started {
            self.started = true;
            let mut pending = Vec::new();
            for ep in 0..self.children.len() {
                self.engine.on_connect(ep);
                let Some(mut child) = self.children[ep].take() else { continue };
                let hellos = child.on_start();
                self.children[ep] = Some(child);
                for hello in hellos {
                    pending.extend(self.engine.handle_message(ep, &hello, self.clock));
                }
            }
            let ups = self.pump(pending);
            debug_assert!(ups.is_empty(), "relay emitted upstream traffic during its handshake");
        }
        vec![self.session.hello()]
    }

    fn on_message(&mut self, bytes: &[u8]) -> Vec<Vec<u8>> {
        let step = self
            .session
            .handle(bytes, &mut self.engine, self.clock)
            .expect("relay upstream session failed");
        if step.done {
            return Vec::new();
        }
        let ups = self.pump(step.actions);
        ups.into_iter().map(|b| self.session.stamp(b)).collect()
    }
}

/// Group a slot-ordered leaf fleet under relays per the topology: one
/// relay per aligned `arity^level` slot block, level by level, until
/// only the root-facing tier remains. Returned peers are the root's
/// direct members, in slot order (their network slots for a tree-sized
/// [`FaultSchedule`] are their positions in this vector).
pub fn build_tree_peers(
    topo: &TreeTopology,
    root_cfg: &ServerConfig,
    leaves: Vec<Box<dyn SimPeer>>,
) -> Vec<Box<dyn SimPeer>> {
    assert_eq!(leaves.len(), topo.leaves, "leaf fleet sized for a different topology");
    let mut nodes: Vec<(usize, Box<dyn SimPeer>)> = leaves.into_iter().enumerate().collect();
    for level in 1..=topo.levels {
        let span = topo.span_at(level);
        let timeout = topo.level_timeout(root_cfg.round_timeout, level);
        let mut grouped: Vec<(usize, Box<dyn SimPeer>)> = Vec::new();
        let mut bucket: Vec<Box<dyn SimPeer>> = Vec::new();
        let mut block = 0usize;
        for (lo, node) in nodes {
            if !bucket.is_empty() && lo / span != block {
                let cfg = root_cfg.relay(block * span, span, timeout);
                grouped.push((block * span, Box::new(RelayNode::new(cfg, mem::take(&mut bucket)))));
            }
            block = lo / span;
            bucket.push(node);
        }
        if !bucket.is_empty() {
            let cfg = root_cfg.relay(block * span, span, timeout);
            grouped.push((block * span, Box::new(RelayNode::new(cfg, bucket))));
        }
        nodes = grouped;
    }
    nodes.into_iter().map(|(_, p)| p).collect()
}

// ---------------------------------------------------------------------------
// the tree harness
// ---------------------------------------------------------------------------

/// Shape of one tree-vs-star simulated federation. Unlike
/// [`super::harness::SimConfig`] the instance is deliberately skinny
/// (`m` rows, a column or three per leaf), so fleets of thousands of
/// leaves stay cheap enough to fuzz.
#[derive(Clone, Debug)]
pub struct TreeSimConfig {
    /// leaf fleet size E
    pub leaves: usize,
    /// relay fan-in (power of two ≥ 2)
    pub arity: usize,
    /// data dimension (rows of M) — small by design
    pub m: usize,
    /// columns per leaf (n = leaves · cols_per_leaf)
    pub cols_per_leaf: usize,
    pub rank: usize,
    pub sparsity: f64,
    pub rounds: usize,
    pub k_local: usize,
    pub problem_seed: u64,
    pub server_seed: u64,
    /// the ROOT's straggler deadline; relay levels step down from it
    pub round_timeout: Duration,
    /// kernel lanes shared by every leaf (0 = the process-wide pool)
    pub threads: usize,
    /// silence one leaf's reply for exactly one round: `(leaf, round)`
    pub mute: Option<(usize, u32)>,
    /// wire codec on every hop (leaf↔relay and relay↔root). Must be
    /// lossless — the tree invariants are bitwise star ≡ tree
    /// identities, so `Delta` here proves the relay re-delta path
    /// end-to-end against the dense star fold.
    pub compression: Compression,
}

impl Default for TreeSimConfig {
    fn default() -> Self {
        TreeSimConfig {
            leaves: 16,
            arity: 4,
            m: 8,
            cols_per_leaf: 3,
            rank: 2,
            sparsity: 0.05,
            rounds: 6,
            k_local: 2,
            problem_seed: 7,
            server_seed: 0xDCF,
            round_timeout: Duration::from_millis(50),
            threads: 0,
            mute: None,
            compression: Compression::None,
        }
    }
}

/// What one tree world produced, with everything classification needs.
struct WorldOutcome {
    outcome: Result<ServerOutcome>,
    materialized: Vec<String>,
    delayed: usize,
    virtual_elapsed: Duration,
}

/// One problem + one leaf fleet, runnable as a star or as a tree.
pub struct TreeSim {
    cfg: TreeSimConfig,
    topo: TreeTopology,
    hyper: FactorHyper,
    problem: RpcaProblem,
    partition: ColumnPartition,
    /// star fault-free outcome, computed on first use (huge fleets that
    /// only assert fan-in bounds never pay for it)
    reference: OnceCell<ServerOutcome>,
}

impl TreeSim {
    pub fn new(cfg: TreeSimConfig) -> Result<Self> {
        if cfg.rounds == 0 || cfg.k_local == 0 || cfg.cols_per_leaf == 0 {
            bail!("tree sim rounds, k_local and cols_per_leaf must be positive");
        }
        if !cfg.compression.is_lossless() {
            bail!("tree sim takes a lossless codec only (its invariants are bitwise)");
        }
        if let Some((leaf, round)) = cfg.mute {
            if leaf >= cfg.leaves || round as usize >= cfg.rounds {
                bail!("mute target ({leaf}, {round}) outside the fleet/horizon");
            }
        }
        let topo = TreeTopology::new(cfg.leaves, cfg.arity)?;
        let n = cfg.leaves * cfg.cols_per_leaf;
        let spec = ProblemSpec { m: cfg.m, n, rank: cfg.rank, sparsity: cfg.sparsity };
        spec.validate().map_err(|e| crate::anyhow!("invalid tree sim problem: {e}"))?;
        let problem = spec.generate(cfg.problem_seed);
        let partition = ColumnPartition::even(n, cfg.leaves);
        let hyper = FactorHyper::default_for(cfg.m, n, cfg.rank);
        Ok(TreeSim { cfg, topo, hyper, problem, partition, reference: OnceCell::new() })
    }

    pub fn config(&self) -> &TreeSimConfig {
        &self.cfg
    }

    pub fn topology(&self) -> &TreeTopology {
        &self.topo
    }

    fn server_cfg(&self) -> ServerConfig {
        let mut cfg =
            ServerConfig::new(self.cfg.m, self.cfg.rank, self.cfg.rounds, self.cfg.k_local);
        cfg.seed = self.cfg.server_seed;
        cfg.round_timeout = self.cfg.round_timeout;
        cfg.fault_policy = FaultPolicy::SkipMissing;
        cfg.compression = self.cfg.compression;
        cfg.err_denominator =
            Some(self.problem.l0.frob_norm_sq() + self.problem.s0.frob_norm_sq());
        cfg
    }

    /// The leaf fleet, slot-ordered. Both worlds call this, so the star
    /// and the tree run byte-identical workers (including the mute
    /// wrapper and the shared kernel pool).
    fn leaf_peers(&self) -> Vec<Box<dyn SimPeer>> {
        let pool =
            (self.cfg.threads > 0).then(|| Arc::new(ThreadPool::new(self.cfg.threads)));
        let n = self.cfg.leaves * self.cfg.cols_per_leaf;
        (0..self.cfg.leaves)
            .map(|i| {
                let (a, b) = self.partition.range(i);
                let cfg = ClientConfig {
                    id: i,
                    job: 0,
                    data: Box::new(self.problem.observed.cols_range(a, b)),
                    hyper: self.hyper,
                    n_frac: (b - a) as f64 / n as f64,
                    polish_sweeps: 1,
                    truth: Some((
                        self.problem.l0.cols_range(a, b),
                        self.problem.s0.cols_range(a, b),
                    )),
                    faults: FaultPlan::default(),
                    compression: self.cfg.compression,
                    dp_sigma: 0.0,
                };
                let leaf: Box<dyn SimPeer> = Box::new(LeafPeer::new(cfg, pool.clone()));
                match self.cfg.mute {
                    Some((target, round)) if target == i => {
                        Box::new(MuteAtRound::new(leaf, round)) as Box<dyn SimPeer>
                    }
                    _ => leaf,
                }
            })
            .collect()
    }

    /// Drive one world (star or tree — whatever `peers` are) under the
    /// given schedule. `Err` is a run-level failure (livelock, illegal
    /// action); a job abort comes back as `Ok` with an `Err` outcome so
    /// the caller can classify it against the schedule.
    fn run_world(
        &self,
        peers: Vec<Box<dyn SimPeer>>,
        schedule: &FaultSchedule,
    ) -> std::result::Result<WorldOutcome, String> {
        if schedule.clients != peers.len() {
            return Err(format!(
                "schedule sized for {} peers, world has {}",
                schedule.clients,
                peers.len()
            ));
        }
        let mut engine = RoundEngine::new();
        engine.add_job(0, self.server_cfg(), schedule.founders());
        let mut net = SimNet::new(schedule.clone(), peers);
        let mut events = 0u64;
        let mut job_done = false;
        while !engine.all_done() {
            events += 1;
            if events > MAX_EVENTS {
                return Err(format!("livelock: no completion within {MAX_EVENTS} events"));
            }
            let timeout = engine
                .next_deadline()
                .map(|d| d.saturating_sub(net.now()))
                .map_or(MAX_IDLE_POLL, |t| t.min(MAX_IDLE_POLL));
            let event =
                net.poll(Some(timeout)).map_err(|e| format!("sim reactor poll failed: {e}"))?;
            let now = net.now();
            let mut actions: VecDeque<Action> = VecDeque::new();
            match event {
                IoEvent::Connected(ep) => engine.on_connect(ep),
                IoEvent::Message(ep, bytes) => {
                    actions.extend(engine.handle_message(ep, &bytes, now));
                }
                IoEvent::Disconnected(ep) => actions.extend(engine.on_disconnect(ep, now)),
                IoEvent::Tick => {}
            }
            actions.extend(engine.poll_deadline(net.now()));
            while let Some(action) = actions.pop_front() {
                match action {
                    Action::Send { ep, bytes } => {
                        if let Err(e) = net.send(ep, &bytes) {
                            return Err(format!("send to endpoint {ep} failed: {e}"));
                        }
                    }
                    Action::Broadcast { peers, body } => {
                        for (ep, seq) in peers {
                            let mut bytes = body.as_ref().clone();
                            restamp_seq(&mut bytes, seq);
                            if let Err(e) = net.send(ep, &bytes) {
                                return Err(format!("broadcast to endpoint {ep} failed: {e}"));
                            }
                        }
                    }
                    Action::Close { ep } => net.close(ep),
                    Action::JobDone { .. } => job_done = true,
                    Action::Upstream { job, .. } => {
                        return Err(format!(
                            "root job {job} emitted an Upstream action (relay-only output)"
                        ));
                    }
                }
            }
        }
        if !job_done {
            return Err("engine terminated without emitting JobDone".to_string());
        }
        let outcome = engine
            .take_result(0)
            .ok_or_else(|| "engine terminated without a job result".to_string())?;
        Ok(WorldOutcome {
            outcome,
            materialized: net.materialized().to_vec(),
            delayed: net.delayed(),
            virtual_elapsed: net.now(),
        })
    }

    /// All E leaves directly under the root (the baseline world). The
    /// schedule must be sized for `leaves` network slots.
    pub fn run_star(&self, schedule: &FaultSchedule) -> Result<ServerOutcome> {
        self.run_world(self.leaf_peers(), schedule).map_err(|d| crate::anyhow!("{d}"))?.outcome
    }

    /// The same leaves grouped under relays per the topology. The
    /// schedule must be sized for [`TreeTopology::top_count`] network
    /// slots — faults target *relays*, and a relay fault hits its whole
    /// subtree at once.
    pub fn run_tree(&self, schedule: &FaultSchedule) -> Result<ServerOutcome> {
        let peers = build_tree_peers(&self.topo, &self.server_cfg(), self.leaf_peers());
        self.run_world(peers, schedule).map_err(|d| crate::anyhow!("{d}"))?.outcome
    }

    /// The star fault-free outcome every clean tree run must match
    /// bitwise. Computed once, on first use.
    pub fn reference(&self) -> &ServerOutcome {
        self.reference.get_or_init(|| {
            let schedule = FaultSchedule::fault_free(
                self.cfg.problem_seed,
                self.cfg.leaves,
                self.cfg.rounds,
            );
            self.run_star(&schedule).expect("fault-free star reference failed")
        })
    }

    /// Per-round leaf participation the world is expected to reach when
    /// nothing was cut (a configured mute costs its one leaf-round).
    fn expected_participants(&self, round: usize) -> usize {
        match self.cfg.mute {
            Some((_, r)) if r as usize == round => self.cfg.leaves - 1,
            _ => self.cfg.leaves,
        }
    }

    /// The exact CLI invocation reproducing `seed` under this shape.
    pub fn replay_command(&self, seed: u64) -> String {
        format!(
            "dcf-pca simulate --topology tree --seeds {}..{} --clients {} --tree-arity {} \
             --m {} --cols-per-leaf {} --rank {} --sparsity {} --rounds {} --k-local {} \
             --problem-seed {} --server-seed {} --timeout-ms {} --codec {}",
            seed,
            seed + 1,
            self.cfg.leaves,
            self.cfg.arity,
            self.cfg.m,
            self.cfg.cols_per_leaf,
            self.cfg.rank,
            self.cfg.sparsity,
            self.cfg.rounds,
            self.cfg.k_local,
            self.cfg.problem_seed,
            self.cfg.server_seed,
            self.cfg.round_timeout.as_millis(),
            self.cfg.compression.cli_name(),
        )
    }

    /// Run the relay-fault schedule drawn from `seed` and check the
    /// tree invariants (see [`Self::check_tree_schedule`]).
    pub fn check_tree_seed(&self, seed: u64) -> std::result::Result<SimReport, Violation> {
        self.check_tree_schedule(&FaultSchedule::draw_tree(
            seed,
            self.topo.top_count(),
            self.cfg.rounds,
        ))
    }

    /// Run one relay-fault schedule against the tree world and check:
    ///
    /// - the run terminates (no panic, no livelock), and only aborts
    ///   when every relay was faulted;
    /// - per round, the root ingests at most `top_count` partials and
    ///   never more leaf updates than the fleet holds;
    /// - calm worlds and recoverable-flap worlds (every fault a
    ///   [`Fault::Disconnect`] inside [`FaultSchedule::under_budget`])
    ///   suffer **zero subtree-wide cuts** and reproduce the star
    ///   reference bit for bit — `U` and the canonical per-round
    ///   telemetry sums exactly equal.
    pub fn check_tree_schedule(
        &self,
        schedule: &FaultSchedule,
    ) -> std::result::Result<SimReport, Violation> {
        let viol = |detail: String| {
            let derived =
                FaultSchedule::draw_tree(schedule.seed, schedule.clients, schedule.rounds);
            let replay = if *schedule == derived {
                self.replay_command(schedule.seed)
            } else {
                format!(
                    "TreeSim::check_tree_schedule with the fault list above (hand-built or \
                     shrunk schedule — not derivable from seed {})",
                    schedule.seed
                )
            };
            Violation { seed: schedule.seed, detail, schedule: schedule.clone(), replay }
        };
        let ran = catch_unwind(AssertUnwindSafe(|| {
            let peers = build_tree_peers(&self.topo, &self.server_cfg(), self.leaf_peers());
            self.run_world(peers, schedule)
        }));
        let world = match ran {
            Ok(Ok(world)) => world,
            Ok(Err(detail)) => return Err(viol(detail)),
            Err(panic) => {
                let msg = crate::testing::panic_message(panic.as_ref());
                return Err(viol(format!("panic during run: {msg}")));
            }
        };
        let mut report = SimReport {
            seed: schedule.seed,
            faults: schedule.faults.len(),
            materialized: world.materialized.len(),
            delayed: world.delayed,
            rounds_run: 0,
            min_participants: 0,
            final_err: None,
            virtual_elapsed: world.virtual_elapsed,
            completed_ok: false,
            bitwise_clean: false,
        };

        let recoverable_flaps_only = !schedule.faults.is_empty()
            && schedule.faults.iter().all(|f| matches!(f, Fault::Disconnect { .. }))
            && schedule.under_budget(self.cfg.round_timeout);

        let out = match world.outcome {
            Err(err) => {
                if recoverable_flaps_only {
                    return Err(viol(format!(
                        "tree job aborted under recoverable relay flaps: {err}"
                    )));
                }
                if schedule.has_healthy_client() {
                    return Err(viol(format!(
                        "tree job aborted despite a fault-free relay: {err}"
                    )));
                }
                return Ok(report);
            }
            Ok(out) => out,
        };
        report.completed_ok = true;
        report.rounds_run = out.rounds.len();
        report.min_participants = out.rounds.iter().map(|r| r.participants).min().unwrap_or(0);

        let top = self.topo.top_count();
        for r in &out.rounds {
            if r.fan_in > top {
                return Err(viol(format!(
                    "round {} ingested {} partials with only {top} top-level relays",
                    r.round, r.fan_in
                )));
            }
            if r.participants > self.cfg.leaves {
                return Err(viol(format!(
                    "round {} counted {} participants in a {}-leaf fleet",
                    r.round, r.participants, self.cfg.leaves
                )));
            }
        }

        // bitwise identity against the star baseline: calm worlds, and
        // flap worlds whose every outage resumes inside the deadline
        let calm = schedule.faults.is_empty() && world.materialized.is_empty();
        if calm || recoverable_flaps_only {
            if out.rounds.len() != self.cfg.rounds {
                return Err(viol(format!(
                    "a recoverable relay fault shortened the run: {} of {} rounds",
                    out.rounds.len(),
                    self.cfg.rounds
                )));
            }
            for r in &out.rounds {
                if r.fan_in != top || r.participants != self.expected_participants(r.round) {
                    return Err(viol(format!(
                        "a recoverable relay fault cut a subtree: round {} fan-in {}/{top}, \
                         participants {}/{}",
                        r.round,
                        r.fan_in,
                        r.participants,
                        self.expected_participants(r.round)
                    )));
                }
            }
            let reference = self.reference();
            if out.u != reference.u {
                return Err(viol(
                    "tree U diverged bitwise from the star run".to_string(),
                ));
            }
            for (a, b) in out.rounds.iter().zip(&reference.rounds) {
                if a.err != b.err || a.mean_grad_norm != b.mean_grad_norm {
                    return Err(viol(format!(
                        "round {} telemetry diverged between tree and star \
                         (canonical span reduction broken)",
                        a.round
                    )));
                }
            }
            report.bitwise_clean = true;
        }
        Ok(report)
    }

    /// Greedy schedule minimization for a failing tree world (same
    /// discipline as [`super::harness::SimHarness::shrink`]).
    pub fn shrink_tree(&self, schedule: &FaultSchedule) -> Option<(FaultSchedule, Violation)> {
        let mut current = schedule.clone();
        let mut violation = match self.check_tree_schedule(&current) {
            Err(v) => v,
            Ok(_) => return None,
        };
        loop {
            let mut progressed = false;
            let mut i = 0;
            while i < current.faults.len() {
                let mut candidate = current.clone();
                candidate.faults.remove(i);
                match self.check_tree_schedule(&candidate) {
                    Err(v) => {
                        current = candidate;
                        violation = v;
                        progressed = true;
                    }
                    Ok(_) => i += 1,
                }
            }
            if !progressed {
                break;
            }
        }
        Some((current, violation))
    }

    /// Sweep a seed range of relay-fault worlds.
    pub fn fuzz_tree(&self, seeds: Range<u64>) -> FuzzSummary {
        let wall = Instant::now();
        let mut summary = FuzzSummary::default();
        for seed in seeds {
            summary.seeds_run += 1;
            match self.check_tree_seed(seed) {
                Ok(report) => {
                    summary.virtual_total += report.virtual_elapsed;
                    summary.reports.push(report);
                }
                Err(violation) => summary.failures.push(violation),
            }
        }
        summary.wall = wall.elapsed();
        summary
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_shape_math() {
        // 16 leaves, arity 4: one relay level of 4, root serves 4
        let t = TreeTopology::new(16, 4).unwrap();
        assert_eq!((t.levels, t.top_span(), t.top_count()), (1, 4, 4));
        assert_eq!(t.relays_per_level(), vec![4]);

        // star when the fleet already fits under the root
        let t = TreeTopology::new(4, 8).unwrap();
        assert_eq!((t.levels, t.top_count()), (0, 4));
        assert_eq!(t.relay_count(), 0);

        // 10k leaves, arity 8: spans 8/64/512/4096, root serves 3
        let t = TreeTopology::new(10_000, 8).unwrap();
        assert_eq!(t.levels, 4);
        assert_eq!(t.top_span(), 4096);
        assert_eq!(t.top_count(), 3);
        assert!(t.top_count() <= t.arity);
        assert_eq!(t.relays_per_level(), vec![1250, 157, 20, 3]);

        // non-power-of-two arity rejected
        assert!(TreeTopology::new(16, 3).is_err());
        assert!(TreeTopology::new(0, 4).is_err());
    }

    #[test]
    fn level_timeouts_nest_strictly() {
        let t = TreeTopology::new(10_000, 8).unwrap();
        let root = Duration::from_millis(50);
        let mut prev = Duration::ZERO;
        for level in 1..=t.levels {
            let w = t.level_timeout(root, level);
            assert!(w > prev, "level {level} window {w:?} not above {prev:?}");
            prev = w;
        }
        assert!(root > prev, "root window must exceed the top relay level's");
    }

    #[test]
    fn tree_schedule_targets_relays_only() {
        let mut crash_seen = false;
        let mut flap_seen = false;
        for seed in 0..64 {
            let s = FaultSchedule::draw_tree(seed, 4, 6);
            assert_eq!(s.clients, 4);
            for f in &s.faults {
                assert!(f.client() < 4, "fault outside the relay slots: {f}");
                match f {
                    Fault::Disconnect { .. } => flap_seen = true,
                    Fault::CrashAt { .. } => crash_seen = true,
                    other => panic!("unexpected tree fault kind: {other}"),
                }
            }
        }
        assert!(crash_seen && flap_seen, "distribution never drew both fault kinds");
    }
}
