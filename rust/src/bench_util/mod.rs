//! Benchmark harness (criterion is not in the offline vendor tree).
//!
//! Provides warmup + sampled timing with mean/σ/percentiles, and aligned
//! table printing used by every `cargo bench` target to emit the rows of
//! the paper's tables/figures.

use std::time::{Duration, Instant};

/// Timing statistics over n samples.
#[derive(Clone, Copy, Debug)]
pub struct Stats {
    pub samples: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

impl Stats {
    pub fn from_secs(mut xs: Vec<f64>) -> Stats {
        assert!(!xs.is_empty());
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let pct = |p: f64| xs[((p * (n - 1) as f64).round() as usize).min(n - 1)];
        Stats {
            samples: n,
            mean,
            stddev: var.sqrt(),
            min: xs[0],
            p50: pct(0.5),
            p95: pct(0.95),
            max: xs[n - 1],
        }
    }
}

/// Benchmark runner: warmup runs then timed samples.
pub struct Bencher {
    pub warmup: usize,
    pub samples: usize,
    /// cap on total sampling time; sampling stops early past this
    pub max_total: Duration,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, samples: 5, max_total: Duration::from_secs(120) }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { warmup: 0, samples: 3, max_total: Duration::from_secs(60) }
    }

    /// Time `f`, returning stats over the sampled runs. The closure's
    /// return value is black-boxed to keep the optimizer honest.
    pub fn run<T>(&self, mut f: impl FnMut() -> T) -> Stats {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut times = Vec::with_capacity(self.samples);
        let start = Instant::now();
        for _ in 0..self.samples {
            let t0 = Instant::now();
            black_box(f());
            times.push(t0.elapsed().as_secs_f64());
            if start.elapsed() > self.max_total && !times.is_empty() {
                break;
            }
        }
        Stats::from_secs(times)
    }
}

/// Optimizer barrier (std::hint::black_box re-export for older idioms).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for bench output.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    widths: Vec<usize>,
}

impl Table {
    pub fn new(columns: &[&str]) -> Self {
        Table {
            widths: columns.iter().map(|c| c.len()).collect(),
            header: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String], widths: &[usize]| {
            let cols: Vec<String> = cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect();
            println!("| {} |", cols.join(" | "));
        };
        line(&self.header, &self.widths);
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep, &self.widths);
        for r in &self.rows {
            line(r, &self.widths);
        }
    }
}

/// Human-friendly duration formatting for bench rows.
pub fn fmt_secs(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1}ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.1}µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.1}ms", s * 1e3)
    } else {
        format!("{s:.2}s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_from_known_values() {
        let s = Stats::from_secs(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.samples, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
    }

    #[test]
    fn bencher_runs_and_times() {
        let b = Bencher { warmup: 1, samples: 3, max_total: Duration::from_secs(10) };
        let mut count = 0;
        let stats = b.run(|| {
            count += 1;
            std::thread::sleep(Duration::from_millis(1));
        });
        assert_eq!(count, 4); // 1 warmup + 3 samples
        assert!(stats.mean >= 0.001);
    }

    #[test]
    fn fmt_secs_ranges() {
        assert!(fmt_secs(2.5).ends_with('s'));
        assert!(fmt_secs(0.002).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("µs"));
        assert!(fmt_secs(5e-9).ends_with("ns"));
    }
}
