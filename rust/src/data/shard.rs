//! `.dcfshard` — the zero-dependency on-disk format for one client's
//! column block, laid out the way the compute stack consumes it.
//!
//! The fused tile pipeline (PR 2) streams a block as independent column
//! panels; this format stores the block **panel-major** so each panel is
//! one contiguous positioned read:
//!
//! ```text
//! offset  size   field
//! 0       8      magic  b"DCFSHRD1"
//! 8       4      version u32 LE (= 1)
//! 12      4      reserved u32 LE (= 0)
//! 16      8      rows u64 LE          (m)
//! 24      8      cols u64 LE          (n_i — this shard's columns)
//! 32      8      panel_width u64 LE   (w — the tile width the payload
//!                                      was materialized at)
//! 40      8      col_offset u64 LE    (first global column, Eq. 6 slot)
//! 48      8      total_cols u64 LE    (global n across all shards)
//! 56      8      seed u64 LE          (generator provenance)
//! 64      8·P    per-panel FNV-1a64 checksums over the panel's bytes
//! 64+8P   …      payload: panel k = rows × w_k f64 LE, row-major
//!                (w_k = min(w, cols − k·w); P = ⌈cols / w⌉)
//! ```
//!
//! All integers and floats are little-endian; f64 bits round-trip
//! exactly, which is what makes a streamed epoch *bitwise* identical to
//! the resident one. Checksums are verified on every panel read (they
//! also catch torn writes), and every failure mode is a typed
//! [`ShardError`] variant so callers and tests can distinguish
//! truncation from corruption from version skew.

use std::fs::File;
use std::io::{self, BufWriter, Seek, SeekFrom, Write};
use std::path::{Path, PathBuf};

use crate::linalg::Mat;

/// File magic: "DCFSHRD" + format generation digit.
pub const MAGIC: [u8; 8] = *b"DCFSHRD1";
/// Current format version (bumped on incompatible layout changes).
pub const VERSION: u32 = 1;
/// Byte offset of the checksum table (fixed-size header above it).
const HEADER_BYTES: u64 = 64;

/// Typed failure modes of the shard format.
#[derive(Debug)]
pub enum ShardError {
    Io(io::Error),
    /// not a `.dcfshard` file at all
    BadMagic { path: PathBuf },
    /// right magic, wrong format generation
    VersionMismatch { path: PathBuf, found: u32, expected: u32 },
    /// file shorter (or longer) than the header's dims imply
    Truncated { path: PathBuf, expected: u64, found: u64 },
    /// a panel's bytes do not hash to the recorded checksum
    ChecksumMismatch { path: PathBuf, panel: usize, recorded: u64, computed: u64 },
    /// header dims are internally inconsistent (e.g. zero panel width)
    BadHeader { path: PathBuf, what: String },
}

impl std::fmt::Display for ShardError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ShardError::Io(e) => write!(f, "shard I/O error: {e}"),
            ShardError::BadMagic { path } => {
                write!(f, "{}: not a .dcfshard file (bad magic)", path.display())
            }
            ShardError::VersionMismatch { path, found, expected } => write!(
                f,
                "{}: shard format version {found} (this build reads {expected})",
                path.display()
            ),
            ShardError::Truncated { path, expected, found } => write!(
                f,
                "{}: truncated or oversized shard ({found} bytes, header implies {expected})",
                path.display()
            ),
            ShardError::ChecksumMismatch { path, panel, recorded, computed } => write!(
                f,
                "{}: panel {panel} checksum mismatch (recorded {recorded:#018x}, \
                 computed {computed:#018x}) — corrupt payload",
                path.display()
            ),
            ShardError::BadHeader { path, what } => {
                write!(f, "{}: bad shard header: {what}", path.display())
            }
        }
    }
}

impl std::error::Error for ShardError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            ShardError::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<io::Error> for ShardError {
    fn from(e: io::Error) -> Self {
        ShardError::Io(e)
    }
}

/// Decoded fixed-size header of a shard.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardHeader {
    pub version: u32,
    pub rows: usize,
    pub cols: usize,
    pub panel_width: usize,
    /// first global column of this shard (its slot in Eq. 6's partition)
    pub col_offset: usize,
    /// global column count across all shards of the run
    pub total_cols: usize,
    /// provenance: the generator seed the data came from (0 = unknown)
    pub seed: u64,
}

impl ShardHeader {
    /// Number of panels in the payload.
    pub fn panel_count(&self) -> usize {
        crate::linalg::panel_count(self.cols, self.panel_width)
    }

    /// Column count of panel `k`.
    pub fn panel_cols(&self, k: usize) -> usize {
        let j0 = k * self.panel_width;
        (j0 + self.panel_width).min(self.cols) - j0
    }

    /// Expected total file size implied by the dims.
    fn expected_file_len(&self) -> u64 {
        HEADER_BYTES
            + 8 * self.panel_count() as u64
            + 8 * self.rows as u64 * self.cols as u64
    }

    /// Byte offset of panel `k`'s payload.
    fn panel_offset(&self, k: usize) -> u64 {
        // panels 0..k all have full width w except never before a ragged
        // one, so the prefix is simply rows·(k·w) entries
        HEADER_BYTES
            + 8 * self.panel_count() as u64
            + 8 * self.rows as u64 * (k * self.panel_width) as u64
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64 step — the single source of truth for the
/// checksum algorithm (the writer hashes panels chunk by chunk, the
/// reader in one pass; both call this).
#[inline]
fn fnv1a64_update(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a 64 over a byte stream — cheap, allocation-free, good enough to
/// catch truncation/bit-rot (this is an integrity check, not crypto).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    fnv1a64_update(FNV_OFFSET, bytes)
}

/// View an f64 slice as its raw bytes (for checksumming / positioned I/O).
/// Alignment is trivially satisfied (f64 → u8).
fn as_bytes(slice: &[f64]) -> &[u8] {
    // SAFETY: same allocation, length scaled by size_of::<f64>, u8 has
    // no validity requirements.
    unsafe { std::slice::from_raw_parts(slice.as_ptr().cast::<u8>(), slice.len() * 8) }
}

fn as_bytes_mut(slice: &mut [f64]) -> &mut [u8] {
    // SAFETY: as above; callers re-normalize endianness after writing.
    unsafe { std::slice::from_raw_parts_mut(slice.as_mut_ptr().cast::<u8>(), slice.len() * 8) }
}

/// Streaming writer: header first, panels in order, checksum table
/// back-patched on [`ShardWriter::finish`]. Buffered throughout — the
/// writer never materializes more than one panel.
pub struct ShardWriter {
    out: BufWriter<File>,
    path: PathBuf,
    header: ShardHeader,
    checksums: Vec<u64>,
}

impl ShardWriter {
    /// Create `path` and write the header (checksum table zeroed until
    /// [`ShardWriter::finish`]).
    pub fn create(path: &Path, header: ShardHeader) -> Result<ShardWriter, ShardError> {
        if header.panel_width == 0 {
            return Err(ShardError::BadHeader {
                path: path.to_path_buf(),
                what: "panel_width must be positive".into(),
            });
        }
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        let mut out = BufWriter::new(File::create(path)?);
        out.write_all(&MAGIC)?;
        out.write_all(&VERSION.to_le_bytes())?;
        out.write_all(&0u32.to_le_bytes())?; // reserved
        for v in [
            header.rows as u64,
            header.cols as u64,
            header.panel_width as u64,
            header.col_offset as u64,
            header.total_cols as u64,
            header.seed,
        ] {
            out.write_all(&v.to_le_bytes())?;
        }
        // placeholder checksum table, patched in finish()
        for _ in 0..header.panel_count() {
            out.write_all(&0u64.to_le_bytes())?;
        }
        Ok(ShardWriter { out, path: path.to_path_buf(), header, checksums: Vec::new() })
    }

    /// Append the next panel (rows × w_k, row-major). Panels must arrive
    /// in order and with the exact widths the header implies.
    pub fn write_panel(&mut self, panel: &[f64]) -> Result<(), ShardError> {
        let k = self.checksums.len();
        // order matters: panel_cols(k) underflows past the last panel,
        // so the count guard must run first
        if k >= self.header.panel_count() {
            return Err(ShardError::BadHeader {
                path: self.path.clone(),
                what: format!("panel {k} written, header implies {}", self.header.panel_count()),
            });
        }
        let expect = self.header.rows * self.header.panel_cols(k);
        if panel.len() != expect {
            return Err(ShardError::BadHeader {
                path: self.path.clone(),
                what: format!(
                    "panel {k} has {} entries, header implies {expect}",
                    panel.len()
                ),
            });
        }
        // hash the LE bytes as written (per-value chunks keep the encode
        // endianness-portable; the incremental form matches fnv1a64)
        let mut h = FNV_OFFSET;
        for v in panel {
            let bytes = v.to_bits().to_le_bytes();
            h = fnv1a64_update(h, &bytes);
            self.out.write_all(&bytes)?;
        }
        self.checksums.push(h);
        Ok(())
    }

    /// Flush, back-patch the checksum table, and close the file.
    pub fn finish(self) -> Result<(), ShardError> {
        let ShardWriter { out, path, header, checksums } = self;
        if checksums.len() != header.panel_count() {
            return Err(ShardError::BadHeader {
                path,
                what: format!(
                    "finish() after {} of {} panels",
                    checksums.len(),
                    header.panel_count()
                ),
            });
        }
        let mut file = out.into_inner().map_err(|e| ShardError::Io(e.into_error()))?;
        file.seek(SeekFrom::Start(HEADER_BYTES))?;
        for c in &checksums {
            file.write_all(&c.to_le_bytes())?;
        }
        file.sync_all()?;
        Ok(())
    }
}

/// Write a resident column block `block` (already sliced to one client)
/// as a shard at `path`. `col_offset`/`total_cols`/`seed` record where
/// the block sits in the global matrix and where the data came from.
pub fn write_block(
    path: &Path,
    block: &Mat,
    panel_width: usize,
    col_offset: usize,
    total_cols: usize,
    seed: u64,
) -> Result<ShardHeader, ShardError> {
    let (m, n_i) = block.shape();
    let header = ShardHeader {
        version: VERSION,
        rows: m,
        cols: n_i,
        panel_width,
        col_offset,
        total_cols,
        seed,
    };
    let mut writer = ShardWriter::create(path, header)?;
    let mut panel = vec![0.0f64; m * panel_width.min(n_i.max(1))];
    for k in 0..header.panel_count() {
        let j0 = k * panel_width;
        let wk = header.panel_cols(k);
        for i in 0..m {
            panel[i * wk..(i + 1) * wk]
                .copy_from_slice(&block.as_slice()[i * n_i + j0..i * n_i + j0 + wk]);
        }
        writer.write_panel(&panel[..m * wk])?;
    }
    writer.finish()?;
    Ok(header)
}

/// Positioned-read access to one shard. All reads go through
/// `pread`-style positioned I/O (no shared cursor), so panels can be
/// fetched concurrently from the panel-parallel dispatch slots, and
/// [`ShardReader::prefetch`] hints the next panel into the page cache —
/// the kernel's readahead is the second buffer of the double-buffering
/// scheme (see the module docs of `data::source`).
pub struct ShardReader {
    file: File,
    path: PathBuf,
    header: ShardHeader,
    checksums: Vec<u64>,
    /// non-unix fallback: serializes the seek+read pairs
    #[cfg(not(unix))]
    pos_lock: std::sync::Mutex<()>,
}

impl ShardReader {
    /// Open and validate `path`: magic, version, and that the file length
    /// matches what the header's dims imply.
    pub fn open(path: &Path) -> Result<ShardReader, ShardError> {
        let file = File::open(path)?;
        let len = file.metadata()?.len();
        let p = || path.to_path_buf();
        if len < HEADER_BYTES {
            return Err(ShardError::Truncated { path: p(), expected: HEADER_BYTES, found: len });
        }
        let mut head = [0u8; HEADER_BYTES as usize];
        pread_exact_file(&file, &mut head, 0)?;
        if head[..8] != MAGIC {
            return Err(ShardError::BadMagic { path: p() });
        }
        let u32_at = |at: usize| u32::from_le_bytes(head[at..at + 4].try_into().unwrap());
        let u64_at = |at: usize| u64::from_le_bytes(head[at..at + 8].try_into().unwrap());
        let version = u32_at(8);
        if version != VERSION {
            return Err(ShardError::VersionMismatch { path: p(), found: version, expected: VERSION });
        }
        let header = ShardHeader {
            version,
            rows: u64_at(16) as usize,
            cols: u64_at(24) as usize,
            panel_width: u64_at(32) as usize,
            col_offset: u64_at(40) as usize,
            total_cols: u64_at(48) as usize,
            seed: u64_at(56),
        };
        if header.panel_width == 0 {
            return Err(ShardError::BadHeader { path: p(), what: "panel_width = 0".into() });
        }
        let expected = header.expected_file_len();
        if len != expected {
            return Err(ShardError::Truncated { path: p(), expected, found: len });
        }
        let panels = header.panel_count();
        let mut table = vec![0u8; 8 * panels];
        pread_exact_file(&file, &mut table, HEADER_BYTES)?;
        let checksums = (0..panels)
            .map(|k| u64::from_le_bytes(table[8 * k..8 * k + 8].try_into().unwrap()))
            .collect();
        Ok(ShardReader {
            file,
            path: path.to_path_buf(),
            header,
            checksums,
            #[cfg(not(unix))]
            pos_lock: std::sync::Mutex::new(()),
        })
    }

    pub fn header(&self) -> &ShardHeader {
        &self.header
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Positioned read of panel `k` into `buf` (resized to rows × w_k;
    /// steady-state callers keep a buffer of capacity rows × w so this
    /// never reallocates). Verifies the panel checksum. Returns w_k.
    pub fn read_panel_into(&self, k: usize, buf: &mut Vec<f64>) -> Result<usize, ShardError> {
        let panels = self.header.panel_count();
        assert!(k < panels, "panel {k} out of range ({panels} panels)");
        let wk = self.header.panel_cols(k);
        let len = self.header.rows * wk;
        buf.resize(len, 0.0);
        self.pread(as_bytes_mut(&mut buf[..len]), self.header.panel_offset(k))?;
        let computed = fnv1a64(as_bytes(&buf[..len]));
        let recorded = self.checksums[k];
        if computed != recorded {
            return Err(ShardError::ChecksumMismatch {
                path: self.path.clone(),
                panel: k,
                recorded,
                computed,
            });
        }
        // decode LE in place (no-op on little-endian targets)
        for x in buf[..len].iter_mut() {
            *x = f64::from_bits(u64::from_le(x.to_bits()));
        }
        Ok(wk)
    }

    /// Positioned exact read with the platform-appropriate cursor
    /// discipline: true `pread` on unix; elsewhere a mutex serializes the
    /// seek+read pairs so concurrent panel fetches cannot interleave.
    fn pread(&self, buf: &mut [u8], off: u64) -> io::Result<()> {
        #[cfg(not(unix))]
        let _guard = self.pos_lock.lock().unwrap();
        pread_exact_file(&self.file, buf, off)
    }

    /// Best-effort readahead hint for panel `k`: asks the kernel to pull
    /// the panel's bytes into the page cache while the caller computes on
    /// the current one. No-op off Linux; never fails.
    pub fn prefetch(&self, k: usize) {
        #[cfg(target_os = "linux")]
        {
            use std::os::unix::io::AsRawFd;
            if k < self.header.panel_count() {
                let off = self.header.panel_offset(k) as i64;
                let len = (8 * self.header.rows * self.header.panel_cols(k)) as i64;
                // SAFETY: plain syscall on an open fd; advisory only.
                unsafe {
                    sys::posix_fadvise(self.file.as_raw_fd(), off, len, sys::POSIX_FADV_WILLNEED);
                }
            }
        }
        #[cfg(not(target_os = "linux"))]
        let _ = k;
    }

    /// Materialize the whole shard as a resident matrix (checksum-verified
    /// panel by panel). Allocating — load path, not the hot path.
    pub fn to_mat(&self) -> Result<Mat, ShardError> {
        let (m, n_i, w) = (self.header.rows, self.header.cols, self.header.panel_width);
        let mut out = Mat::zeros(m, n_i);
        let mut buf = Vec::new();
        for k in 0..self.header.panel_count() {
            let wk = self.read_panel_into(k, &mut buf)?;
            let j0 = k * w;
            for i in 0..m {
                out.row_mut(i)[j0..j0 + wk].copy_from_slice(&buf[i * wk..(i + 1) * wk]);
            }
        }
        Ok(out)
    }
}

/// `pread`-style positioned exact read: no shared cursor on unix, a
/// mutex-serialized seek+read elsewhere.
#[cfg(unix)]
fn pread_exact_file(file: &File, buf: &mut [u8], off: u64) -> io::Result<()> {
    use std::os::unix::fs::FileExt;
    file.read_exact_at(buf, off)
}

#[cfg(not(unix))]
fn pread_exact_file(file: &File, buf: &mut [u8], off: u64) -> io::Result<()> {
    use std::io::Read;
    // &File implements Read/Seek; callers additionally hold pos_lock so
    // concurrent panel fetches do not interleave their cursors — on the
    // only non-unix dev targets this is the portable fallback, not the
    // perf path.
    let mut f = file;
    f.seek(SeekFrom::Start(off))?;
    f.read_exact(buf)
}

#[cfg(target_os = "linux")]
mod sys {
    pub const POSIX_FADV_WILLNEED: i32 = 3;
    extern "C" {
        /// Direct binding (the C library is linked anyway) — same
        /// zero-dependency pattern as `util::cputime`.
        pub fn posix_fadvise(fd: i32, offset: i64, len: i64, advice: i32) -> i32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("dcfshard-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn roundtrip(m: usize, n: usize, w: usize, name: &str) {
        let mut rng = Pcg64::new((m * 31 + n * 7 + w) as u64);
        let block = if m * n > 0 { Mat::gaussian(m, n, &mut rng) } else { Mat::zeros(m, n) };
        let path = tmp(name);
        let header = write_block(&path, &block, w, 3, n + 5, 42).unwrap();
        assert_eq!(header.panel_count(), crate::linalg::panel_count(n, w));
        let reader = ShardReader::open(&path).unwrap();
        assert_eq!(reader.header(), &header);
        assert_eq!(reader.header().col_offset, 3);
        assert_eq!(reader.header().total_cols, n + 5);
        assert_eq!(reader.header().seed, 42);
        let back = reader.to_mat().unwrap();
        assert_eq!(back, block, "bitwise roundtrip failed at {m}x{n} w={w}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn roundtrip_every_panel_width() {
        // every width from degenerate 1 through > n (single panel),
        // covering ragged last panels at each divisor class
        let (m, n) = (13, 11);
        for w in 1..=n + 2 {
            roundtrip(m, n, w, &format!("w{w}.dcfshard"));
        }
    }

    #[test]
    fn roundtrip_edge_shapes() {
        roundtrip(7, 1, 4, "one-col.dcfshard"); // 1-column block
        roundtrip(1, 9, 4, "one-row.dcfshard"); // 1-row block
        roundtrip(5, 0, 4, "no-cols.dcfshard"); // empty payload
        roundtrip(33, 57, 16, "odd.dcfshard"); // odd non-divisible shape
    }

    #[test]
    fn truncation_is_typed() {
        let mut rng = Pcg64::new(5);
        let block = Mat::gaussian(6, 9, &mut rng);
        let path = tmp("trunc.dcfshard");
        write_block(&path, &block, 4, 0, 9, 0).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 17]).unwrap();
        match ShardReader::open(&path) {
            Err(ShardError::Truncated { expected, found, .. }) => {
                assert_eq!(expected, full.len() as u64);
                assert_eq!(found, full.len() as u64 - 17);
            }
            other => panic!("expected Truncated, got {other:?}"),
        }
        // shorter than even the fixed header
        std::fs::write(&path, &full[..32]).unwrap();
        assert!(matches!(ShardReader::open(&path), Err(ShardError::Truncated { .. })));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn corruption_and_version_are_typed() {
        let mut rng = Pcg64::new(6);
        let block = Mat::gaussian(6, 9, &mut rng);
        let path = tmp("corrupt.dcfshard");
        write_block(&path, &block, 4, 0, 9, 0).unwrap();
        let pristine = std::fs::read(&path).unwrap();

        // flip one payload byte in the last (ragged 6×1) panel → checksum
        // mismatch on read; the panel occupies the file's final 48 bytes
        let mut bad = pristine.clone();
        let payload_at = bad.len() - 45;
        bad[payload_at] ^= 0xFF;
        std::fs::write(&path, &bad).unwrap();
        let reader = ShardReader::open(&path).unwrap(); // header still fine
        let mut buf = Vec::new();
        let last = reader.header().panel_count() - 1;
        match reader.read_panel_into(last, &mut buf) {
            Err(ShardError::ChecksumMismatch { panel, .. }) => assert_eq!(panel, last),
            other => panic!("expected ChecksumMismatch, got {other:?}"),
        }
        // earlier panels are untouched and still verify
        assert!(reader.read_panel_into(0, &mut buf).is_ok());

        // version bump → VersionMismatch
        let mut vbad = pristine.clone();
        vbad[8] = 99;
        std::fs::write(&path, &vbad).unwrap();
        assert!(matches!(
            ShardReader::open(&path),
            Err(ShardError::VersionMismatch { found: 99, expected: VERSION, .. })
        ));

        // magic stomp → BadMagic
        let mut mbad = pristine;
        mbad[0] = b'X';
        std::fs::write(&path, &mbad).unwrap();
        assert!(matches!(ShardReader::open(&path), Err(ShardError::BadMagic { .. })));
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn writer_rejects_wrong_panel_shapes() {
        let path = tmp("shape.dcfshard");
        let header = ShardHeader {
            version: VERSION,
            rows: 4,
            cols: 6,
            panel_width: 4,
            col_offset: 0,
            total_cols: 6,
            seed: 0,
        };
        let mut w = ShardWriter::create(&path, header).unwrap();
        assert!(matches!(w.write_panel(&[0.0; 7]), Err(ShardError::BadHeader { .. })));
        w.write_panel(&[0.0; 16]).unwrap(); // panel 0: 4×4
        // premature finish (panel 1 missing) is rejected
        assert!(matches!(w.finish(), Err(ShardError::BadHeader { .. })));
        // one panel too many is a typed error, not a panic
        let mut w = ShardWriter::create(&path, header).unwrap();
        w.write_panel(&[0.0; 16]).unwrap(); // panel 0: 4×4
        w.write_panel(&[0.0; 8]).unwrap(); // panel 1 (ragged): 4×2
        assert!(matches!(w.write_panel(&[0.0; 8]), Err(ShardError::BadHeader { .. })));
        w.finish().unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn fnv_reference_vectors() {
        // published FNV-1a 64 test vectors
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
