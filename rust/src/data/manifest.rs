//! Shard manifests: a small JSON sidecar describing how a data matrix
//! was partitioned into per-client `.dcfshard` files (paper Eq. 6's
//! `M = [M₁ … M_E]`), so `solve`, `worker`, and tests can reassemble the
//! federation without ever materializing M.
//!
//! Shard paths are stored relative to the manifest; [`ShardManifest::load`]
//! resolves them against the manifest's directory, so a generated
//! directory can be moved or mounted elsewhere wholesale.

use std::collections::BTreeMap;
use std::path::Path;

use crate::error::{Context, Result};
use crate::linalg::{tile, Mat};
use crate::rpca::partition::ColumnPartition;
use crate::util::json::Json;
use crate::{anyhow, ensure};

use super::shard::write_block;

/// One client's shard in a manifest.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardEntry {
    pub client: usize,
    /// path to the `.dcfshard` file (resolved against the manifest dir
    /// after [`ShardManifest::load`])
    pub path: String,
    /// first global column of this client's block
    pub col_offset: usize,
    /// this client's column count n_i
    pub cols: usize,
}

/// Manifest for one sharded data matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct ShardManifest {
    pub rows: usize,
    pub total_cols: usize,
    pub seed: u64,
    /// generator provenance, if the data is a synthetic instance: lets
    /// `solve --data` regenerate ground truth for error telemetry
    pub rank: Option<usize>,
    pub sparsity: Option<f64>,
    pub shards: Vec<ShardEntry>,
}

impl ShardManifest {
    /// The column partition the shards cover. Errors unless the shards
    /// tile `[0, total_cols)` contiguously in client order.
    pub fn partition(&self) -> Result<ColumnPartition> {
        ensure!(!self.shards.is_empty(), "manifest has no shards");
        let mut at = 0;
        for (i, s) in self.shards.iter().enumerate() {
            ensure!(
                s.client == i && s.col_offset == at && s.cols > 0,
                "shard {i} does not tile the columns contiguously \
                 (client {}, offset {} ≠ {at}, cols {})",
                s.client,
                s.col_offset,
                s.cols
            );
            at += s.cols;
        }
        ensure!(
            at == self.total_cols,
            "shards cover {at} columns, manifest claims {}",
            self.total_cols
        );
        Ok(ColumnPartition::from_sizes(
            &self.shards.iter().map(|s| s.cols).collect::<Vec<_>>(),
        ))
    }

    /// Serialize to JSON at `path` (shard paths are written as given —
    /// keep them relative for relocatable manifests).
    pub fn save(&self, path: &Path) -> Result<()> {
        let mut obj = BTreeMap::new();
        obj.insert("version".into(), Json::Num(1.0));
        obj.insert("rows".into(), Json::Num(self.rows as f64));
        obj.insert("total_cols".into(), Json::Num(self.total_cols as f64));
        // seed as a string: the JSON layer stores numbers as f64, which
        // would silently round u64 seeds above 2^53
        obj.insert("seed".into(), Json::Str(self.seed.to_string()));
        if let Some(r) = self.rank {
            obj.insert("rank".into(), Json::Num(r as f64));
        }
        if let Some(s) = self.sparsity {
            obj.insert("sparsity".into(), Json::Num(s));
        }
        let shards = self
            .shards
            .iter()
            .map(|s| {
                let mut e = BTreeMap::new();
                e.insert("client".into(), Json::Num(s.client as f64));
                e.insert("path".into(), Json::Str(s.path.clone()));
                e.insert("col_offset".into(), Json::Num(s.col_offset as f64));
                e.insert("cols".into(), Json::Num(s.cols as f64));
                Json::Obj(e)
            })
            .collect();
        obj.insert("shards".into(), Json::Arr(shards));
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent).ok();
            }
        }
        std::fs::write(path, format!("{}\n", Json::Obj(obj)))
            .with_context(|| format!("writing manifest {}", path.display()))
    }

    /// Load a manifest, resolving each shard path against the manifest's
    /// directory.
    pub fn load(path: &Path) -> Result<ShardManifest> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading manifest {}", path.display()))?;
        let j = Json::parse(&text)
            .map_err(|e| anyhow!("{}: {e}", path.display()))?;
        let field = |name: &str| {
            j.get(name)
                .and_then(Json::as_usize)
                .with_context(|| format!("{}: missing/invalid '{name}'", path.display()))
        };
        let version = field("version")?;
        ensure!(version == 1, "{}: unsupported manifest version {version}", path.display());
        let dir = path.parent().unwrap_or(Path::new(""));
        let shards_json = j
            .get("shards")
            .and_then(Json::as_arr)
            .with_context(|| format!("{}: missing 'shards'", path.display()))?;
        let mut shards = Vec::with_capacity(shards_json.len());
        for (i, s) in shards_json.iter().enumerate() {
            let sfield = |name: &str| {
                s.get(name)
                    .and_then(Json::as_usize)
                    .with_context(|| format!("{}: shard {i}: missing/invalid '{name}'", path.display()))
            };
            let rel = s
                .get("path")
                .and_then(Json::as_str)
                .with_context(|| format!("{}: shard {i}: missing 'path'", path.display()))?;
            shards.push(ShardEntry {
                client: sfield("client")?,
                path: dir.join(rel).to_string_lossy().into_owned(),
                col_offset: sfield("col_offset")?,
                cols: sfield("cols")?,
            });
        }
        let seed = match j.get("seed") {
            Some(Json::Str(s)) => s
                .parse::<u64>()
                .map_err(|_| anyhow!("{}: invalid 'seed' \"{s}\"", path.display()))?,
            // tolerate numeric seeds (hand-written manifests)
            Some(Json::Num(n)) => *n as u64,
            _ => 0,
        };
        Ok(ShardManifest {
            rows: field("rows")?,
            total_cols: field("total_cols")?,
            seed,
            rank: j.get("rank").and_then(Json::as_usize),
            sparsity: j.get("sparsity").and_then(Json::as_f64),
            shards,
        })
    }
}

/// Split `m` by `partition` and write one `.dcfshard` per client next to
/// the manifest: `<prefix>.shard<i>.dcfshard` + `<prefix>.manifest.json`.
/// Panel width per shard is the shape-derived tile width of that client's
/// block — the same decomposition a resident split would use, which is
/// what makes streamed runs bitwise identical to in-memory ones.
/// Returns the manifest (with paths relative to its directory, as saved).
pub fn write_shards(
    m: &Mat,
    partition: &ColumnPartition,
    prefix: &Path,
    seed: u64,
    provenance: Option<(usize, f64)>,
) -> Result<ShardManifest> {
    ensure!(
        partition.total_cols() == m.cols(),
        "partition covers {} columns, matrix has {}",
        partition.total_cols(),
        m.cols()
    );
    let stem = prefix
        .file_name()
        .with_context(|| format!("shard prefix {} has no file name", prefix.display()))?
        .to_string_lossy()
        .into_owned();
    let dir = prefix.parent().unwrap_or(Path::new("")).to_path_buf();
    let mut shards = Vec::with_capacity(partition.num_clients());
    for (i, (a, b)) in partition.ranges().enumerate() {
        let block = m.cols_range(a, b);
        let name = format!("{stem}.shard{i}.dcfshard");
        let w = tile::panel_width(block.rows(), block.cols());
        write_block(&dir.join(&name), &block, w, a, m.cols(), seed)?;
        shards.push(ShardEntry { client: i, path: name, col_offset: a, cols: b - a });
    }
    let manifest = ShardManifest {
        rows: m.rows(),
        total_cols: m.cols(),
        seed,
        rank: provenance.map(|(r, _)| r),
        sparsity: provenance.map(|(_, s)| s),
        shards,
    };
    manifest.save(&dir.join(format!("{stem}.manifest.json")))?;
    Ok(manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{DataSource, ShardSource};
    use crate::rng::Pcg64;

    fn tmpdir() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("dcfmanifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn write_load_reassemble_roundtrip() {
        let mut rng = Pcg64::new(4);
        let m = Mat::gaussian(12, 31, &mut rng);
        let partition = ColumnPartition::even(31, 4);
        let prefix = tmpdir().join("round");
        let saved = write_shards(&m, &partition, &prefix, 99, Some((3, 0.05))).unwrap();
        assert_eq!(saved.shards.len(), 4);

        let loaded = ShardManifest::load(&prefix.with_file_name("round.manifest.json")).unwrap();
        assert_eq!(loaded.rows, 12);
        assert_eq!(loaded.total_cols, 31);
        assert_eq!(loaded.seed, 99);
        assert_eq!(loaded.rank, Some(3));
        assert_eq!(loaded.sparsity, Some(0.05));
        assert_eq!(loaded.partition().unwrap(), partition);

        // reassemble the matrix from the streamed shards, bitwise
        let blocks: Vec<Mat> = loaded
            .shards
            .iter()
            .map(|s| ShardSource::open(Path::new(&s.path)).unwrap().to_mat().unwrap())
            .collect();
        assert_eq!(partition.assemble(&blocks), m);
    }

    #[test]
    fn non_contiguous_manifest_rejected() {
        let mut man = ShardManifest {
            rows: 4,
            total_cols: 10,
            seed: 0,
            rank: None,
            sparsity: None,
            shards: vec![
                ShardEntry { client: 0, path: "a".into(), col_offset: 0, cols: 5 },
                ShardEntry { client: 1, path: "b".into(), col_offset: 6, cols: 4 },
            ],
        };
        assert!(man.partition().is_err(), "gap at column 5 must be rejected");
        man.shards[1].col_offset = 5;
        assert!(man.partition().is_err(), "coverage 9 ≠ 10 must be rejected");
        man.shards[1].cols = 5;
        assert!(man.partition().is_ok());
    }

    #[test]
    fn seed_roundtrips_above_f64_precision() {
        let p = tmpdir().join("seed.manifest.json");
        let man = ShardManifest {
            rows: 1,
            total_cols: 1,
            seed: (1u64 << 53) + 1, // not representable as f64
            rank: None,
            sparsity: None,
            shards: vec![ShardEntry { client: 0, path: "x".into(), col_offset: 0, cols: 1 }],
        };
        man.save(&p).unwrap();
        assert_eq!(ShardManifest::load(&p).unwrap().seed, (1u64 << 53) + 1);
    }

    #[test]
    fn load_rejects_garbage() {
        let p = tmpdir().join("bad.manifest.json");
        std::fs::write(&p, "{not json").unwrap();
        assert!(ShardManifest::load(&p).is_err());
        std::fs::write(&p, r#"{"version": 2, "rows": 1, "total_cols": 1, "shards": []}"#).unwrap();
        assert!(ShardManifest::load(&p).is_err(), "future versions must be rejected");
    }
}
