//! `DataSource` — the data-ownership contract of the compute stack.
//!
//! Since PR 2 the hot path consumes a client's block M_i strictly as a
//! sequence of column panels, one DRAM pass per sweep. This trait makes
//! that access pattern the *interface*: the factorization kernels
//! (`algorithms::factor`) no longer hold `&Mat` — they ask a source for
//! panel `k` and get back a [`PanelView`]. Two families implement it:
//!
//! - **Resident** ([`Mat`] itself, and [`MatrixSource`] when a custom
//!   panel width is needed): `panel()` is a zero-copy view into the
//!   in-memory matrix — exactly the indexing the kernels performed
//!   before, so this refactor costs the resident path nothing.
//! - **Streaming** ([`ShardSource`]): `panel()` is a positioned read
//!   from a `.dcfshard` file into the caller's per-slot buffer (one of
//!   `Workspace::io`'s lanes), plus a readahead hint for the slot's
//!   *next* panel. The panel being computed on and the panel the kernel
//!   is pulling into the page cache form the two halves of a double
//!   buffer — compute and I/O overlap without any extra thread, and the
//!   steady-state epoch still allocates nothing (buffers live in the
//!   workspace; asserted by a counting-allocator test below).
//!
//! Determinism: a source fixes the panel width, the kernels derive the
//! panel decomposition from it, and the f64 payload round-trips bitwise
//! — so a streamed epoch is *bit-identical* to the resident epoch on the
//! same data at any thread count (pinned in `tests/data_stream.rs`).

use std::path::Path;

use crate::error::{Context, Result};
use crate::linalg::{tile, Mat, PanelView};

use super::shard::{ShardHeader, ShardReader};

/// A provider of one client block's column panels. See the module docs.
pub trait DataSource: Send + Sync {
    /// Block row count m.
    fn rows(&self) -> usize;

    /// Block column count n_i.
    fn cols(&self) -> usize;

    /// Panel width w of this source's decomposition. Shape-derived
    /// (`tile::panel_width`) for resident sources; recorded in the file
    /// header for shards.
    fn panel_width(&self) -> usize;

    /// Number of panels covering the block.
    fn panel_count(&self) -> usize {
        tile::panel_count(self.cols(), self.panel_width())
    }

    /// Fetch panel `k` (columns `[k·w, min((k+1)·w, n_i))`). `buf` is the
    /// caller's reusable panel buffer — streaming sources fill it,
    /// resident sources ignore it and return a zero-copy view.
    /// `prefetch` names the panel the caller will ask for next (its
    /// slot's next claim), letting streaming sources overlap the next
    /// read with the current compute.
    fn panel<'a>(
        &'a self,
        k: usize,
        prefetch: Option<usize>,
        buf: &'a mut Vec<f64>,
    ) -> Result<PanelView<'a>>;

    /// The resident matrix, if this source holds one (backends that need
    /// the whole block at once — e.g. the PJRT artifact path — use this
    /// to skip materialization).
    fn as_resident(&self) -> Option<&Mat> {
        None
    }

    /// Materialize the block as a resident matrix (allocating; load
    /// path, not the hot path).
    fn to_mat(&self) -> Result<Mat> {
        if let Some(m) = self.as_resident() {
            return Ok(m.clone());
        }
        let (m, n_i, w) = (self.rows(), self.cols(), self.panel_width());
        let mut out = Mat::zeros(m, n_i);
        let mut buf = Vec::new();
        for k in 0..self.panel_count() {
            let j0 = k * w;
            let wk = (j0 + w).min(n_i) - j0;
            let view = self.panel(k, None, &mut buf)?;
            for i in 0..m {
                out.row_mut(i)[j0..j0 + wk].copy_from_slice(view.row(i, wk));
            }
        }
        Ok(out)
    }
}

/// Every resident matrix is a `DataSource` with the shape-derived panel
/// width — which is why the whole existing resident call surface
/// (`&problem.observed` and friends) kept compiling through this
/// refactor: `&Mat` coerces to `&dyn DataSource` at every call site.
impl DataSource for Mat {
    fn rows(&self) -> usize {
        Mat::rows(self)
    }

    fn cols(&self) -> usize {
        Mat::cols(self)
    }

    fn panel_width(&self) -> usize {
        tile::panel_width(Mat::rows(self), Mat::cols(self))
    }

    fn panel<'a>(
        &'a self,
        k: usize,
        _prefetch: Option<usize>,
        _buf: &'a mut Vec<f64>,
    ) -> Result<PanelView<'a>> {
        let w = DataSource::panel_width(self);
        debug_assert!(k * w < Mat::cols(self), "panel {k} out of range");
        Ok(PanelView::new(self.as_slice(), Mat::cols(self), k * w))
    }

    fn as_resident(&self) -> Option<&Mat> {
        Some(self)
    }
}

/// An owned resident source with an explicit panel width — the parity
/// twin of a [`ShardSource`] written at the same width (tests pin the
/// two bitwise against each other at arbitrary widths).
pub struct MatrixSource {
    mat: Mat,
    width: usize,
}

impl MatrixSource {
    /// Resident source at the shape-derived tile width.
    pub fn new(mat: Mat) -> Self {
        let width = tile::panel_width(mat.rows(), mat.cols());
        MatrixSource { mat, width }
    }

    /// Resident source at an explicit panel width.
    pub fn with_panel_width(mat: Mat, width: usize) -> Self {
        assert!(width >= 1, "panel width must be positive");
        MatrixSource { mat, width }
    }

    pub fn into_inner(self) -> Mat {
        self.mat
    }
}

impl DataSource for MatrixSource {
    fn rows(&self) -> usize {
        self.mat.rows()
    }

    fn cols(&self) -> usize {
        self.mat.cols()
    }

    fn panel_width(&self) -> usize {
        self.width
    }

    fn panel<'a>(
        &'a self,
        k: usize,
        _prefetch: Option<usize>,
        _buf: &'a mut Vec<f64>,
    ) -> Result<PanelView<'a>> {
        debug_assert!(k * self.width < self.mat.cols().max(1), "panel {k} out of range");
        Ok(PanelView::new(self.mat.as_slice(), self.mat.cols(), k * self.width))
    }

    fn as_resident(&self) -> Option<&Mat> {
        Some(&self.mat)
    }
}

/// Out-of-core source: panels stream from a `.dcfshard` file by
/// positioned read, checksum-verified, with page-cache readahead of the
/// slot's next panel. The whole block is never resident — peak working
/// set per slot is one m×w panel buffer in the workspace.
pub struct ShardSource {
    reader: ShardReader,
}

impl ShardSource {
    /// Open and validate a shard file.
    pub fn open(path: &Path) -> Result<Self> {
        let reader = ShardReader::open(path)
            .with_context(|| format!("opening shard {}", path.display()))?;
        Ok(ShardSource { reader })
    }

    pub fn header(&self) -> &ShardHeader {
        self.reader.header()
    }
}

impl DataSource for ShardSource {
    fn rows(&self) -> usize {
        self.reader.header().rows
    }

    fn cols(&self) -> usize {
        self.reader.header().cols
    }

    fn panel_width(&self) -> usize {
        self.reader.header().panel_width
    }

    fn panel<'a>(
        &'a self,
        k: usize,
        prefetch: Option<usize>,
        buf: &'a mut Vec<f64>,
    ) -> Result<PanelView<'a>> {
        let wk = self.reader.read_panel_into(k, buf)?;
        if let Some(next) = prefetch {
            // overlap the slot's next read with this panel's compute:
            // the kernel pulls `next` into the page cache while we work
            self.reader.prefetch(next);
        }
        Ok(PanelView::new(&buf[..self.rows() * wk], wk, 0))
    }

    fn to_mat(&self) -> Result<Mat> {
        Ok(self.reader.to_mat()?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::shard::write_block;
    use crate::rng::Pcg64;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join(format!("dcfsource-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn mat_is_a_zero_copy_source() {
        let mut rng = Pcg64::new(1);
        let m = Mat::gaussian(20, 30, &mut rng);
        let src: &dyn DataSource = &m;
        assert_eq!(src.rows(), 20);
        assert_eq!(src.cols(), 30);
        assert_eq!(src.panel_width(), tile::panel_width(20, 30));
        assert!(src.as_resident().is_some());
        let mut buf = Vec::new();
        let w = src.panel_width();
        for k in 0..src.panel_count() {
            let j0 = k * w;
            let wk = (j0 + w).min(30) - j0;
            let view = src.panel(k, None, &mut buf).unwrap();
            for i in 0..20 {
                assert_eq!(view.row(i, wk), &m.as_slice()[i * 30 + j0..i * 30 + j0 + wk]);
            }
        }
        assert!(buf.is_empty(), "resident sources must not touch the io buffer");
        assert_eq!(src.to_mat().unwrap(), m);
    }

    #[test]
    fn shard_source_streams_identical_values() {
        let mut rng = Pcg64::new(2);
        let m = Mat::gaussian(17, 23, &mut rng);
        let path = tmp("stream.dcfshard");
        let w = tile::panel_width(17, 23);
        write_block(&path, &m, w, 0, 23, 7).unwrap();
        let src = ShardSource::open(&path).unwrap();
        assert_eq!(src.rows(), 17);
        assert_eq!(src.cols(), 23);
        assert_eq!(src.panel_width(), w);
        assert!(src.as_resident().is_none());
        let mut buf = Vec::new();
        for k in 0..src.panel_count() {
            let j0 = k * w;
            let wk = (j0 + w).min(23) - j0;
            let next = if k + 1 < src.panel_count() { Some(k + 1) } else { None };
            let view = src.panel(k, next, &mut buf).unwrap();
            for i in 0..17 {
                assert_eq!(view.row(i, wk), &m.as_slice()[i * 23 + j0..i * 23 + j0 + wk]);
            }
        }
        assert_eq!(src.to_mat().unwrap(), m, "materialized shard must be bitwise equal");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn streamed_epoch_steady_state_is_allocation_free() {
        // the out-of-core resident-set pin: once the per-client
        // workspace (with its presized io lanes) exists, a streamed
        // local epoch — J×K sweeps + gradients + curvature, every panel
        // a positioned disk read — performs zero heap allocations on the
        // measuring thread. Peak working set is the workspace + (V, S),
        // never the block.
        use crate::algorithms::factor::{ClientState, FactorHyper};
        use crate::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
        use crate::linalg::Workspace;
        use crate::rpca::problem::ProblemSpec;

        let p = ProblemSpec::square(48, 3, 0.05).generate(9);
        let path = tmp("zeroalloc.dcfshard");
        let w = tile::panel_width(48, 48);
        write_block(&path, &p.observed, w, 0, 48, 9).unwrap();
        let src = ShardSource::open(&path).unwrap();
        let hyper = FactorHyper::default_for(48, 48, 3);
        let mut rng = Pcg64::new(8);
        let mut u = Mat::gaussian(48, 3, &mut rng);
        let mut state = ClientState::zeros(48, 48, 3);
        let mut ws = Workspace::for_source(&src, 3);
        assert!(ws.io.iter().all(|l| l.len() == 48 * w), "io lanes presized for streaming");
        let kernel = NativeKernel::new();
        // warm-up epoch (first call settles lazy state like TLS)
        kernel.local_epoch(&mut u, &src, &mut state, &hyper, 1.0, 1e-3, 2, &mut ws).unwrap();
        let (res, allocs) = crate::alloc_counter::measure(|| {
            kernel.local_epoch(&mut u, &src, &mut state, &hyper, 1.0, 1e-3, 2, &mut ws)
        });
        res.unwrap();
        assert_eq!(allocs, 0, "streamed local epoch allocated {allocs} times after warm-up");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn matrix_source_honours_custom_width() {
        let mut rng = Pcg64::new(3);
        let m = Mat::gaussian(6, 10, &mut rng);
        let src = MatrixSource::with_panel_width(m.clone(), 3);
        assert_eq!(src.panel_width(), 3);
        assert_eq!(src.panel_count(), 4); // 3+3+3+1
        let mut buf = Vec::new();
        let view = src.panel(3, None, &mut buf).unwrap(); // ragged last
        for i in 0..6 {
            assert_eq!(view.row(i, 1), &m.as_slice()[i * 10 + 9..i * 10 + 10]);
        }
        assert_eq!(src.into_inner(), m);
    }
}
