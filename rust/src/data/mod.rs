//! Out-of-core data layer: the columnar `.dcfshard` store and the
//! [`DataSource`] abstraction the compute stack streams panels through.
//!
//! - [`shard`] — the on-disk format: versioned header, panel-major
//!   f64-LE payload, per-panel checksums; positioned-read access.
//! - [`source`] — the [`DataSource`] trait (resident [`Mat`]/
//!   [`MatrixSource`] + streaming [`ShardSource`]) consumed by
//!   `algorithms::factor`, the kernels, and the coordinator clients.
//! - [`manifest`] — per-client shard manifests mapping a
//!   `ColumnPartition` onto shard files for `solve`/`worker`/tests.
//!
//! [`Mat`]: crate::linalg::Mat

pub mod manifest;
pub mod shard;
pub mod source;

pub use manifest::{write_shards, ShardEntry, ShardManifest};
pub use shard::{ShardError, ShardHeader, ShardReader, ShardWriter};
pub use source::{DataSource, MatrixSource, ShardSource};
