//! TOML-subset parser (the offline tree has no `toml` crate).
//!
//! Supported: `[section]` and `[section.sub]` headers, `key = value` with
//! strings, integers, floats, booleans, and homogeneous flat arrays;
//! `#` comments. Unsupported (rejected, not silently ignored): multi-line
//! strings, inline tables, datetimes, array-of-tables.

use std::collections::BTreeMap;

use crate::bail;
use crate::error::{Context, Result};

/// A parsed value.
#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<TomlValue>),
}

impl TomlValue {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            TomlValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_int(&self) -> Option<i64> {
        match self {
            TomlValue::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_int().filter(|i| *i >= 0).map(|i| i as usize)
    }

    pub fn as_float(&self) -> Option<f64> {
        match self {
            TomlValue::Float(f) => Some(*f),
            TomlValue::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            TomlValue::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[TomlValue]> {
        match self {
            TomlValue::Array(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: `section.key → value`. Root-level keys use the
/// empty section name "".
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TomlDoc {
    entries: BTreeMap<(String, String), TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .with_context(|| format!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() || name.contains('[') {
                    bail!("line {}: bad section name '{name}'", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let (key, value) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", lineno + 1))?;
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(value.trim())
                .with_context(|| format!("line {}: bad value for '{key}'", lineno + 1))?;
            let id = (section.clone(), key.to_string());
            if doc.entries.contains_key(&id) {
                bail!("line {}: duplicate key '{key}' in [{section}]", lineno + 1);
            }
            doc.entries.insert(id, value);
        }
        Ok(doc)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&TomlValue> {
        self.entries.get(&(section.to_string(), key.to_string()))
    }

    /// All keys of a section (for unknown-key validation).
    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.entries
            .keys()
            .filter(|(s, _)| s == section)
            .map(|(_, k)| k.as_str())
            .collect()
    }

    pub fn sections(&self) -> Vec<&str> {
        let mut out: Vec<&str> = self.entries.keys().map(|(s, _)| s.as_str()).collect();
        out.sort_unstable();
        out.dedup();
        out
    }
}

fn strip_comment(line: &str) -> &str {
    // a `#` inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<TomlValue> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').context("unterminated string")?;
        if inner.contains('"') {
            bail!("escaped quotes not supported in this TOML subset");
        }
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if text == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if text == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest.strip_suffix(']').context("unterminated array")?.trim();
        if inner.is_empty() {
            return Ok(TomlValue::Array(Vec::new()));
        }
        let items: Result<Vec<TomlValue>> =
            inner.split(',').map(|s| parse_value(s.trim())).collect();
        return Ok(TomlValue::Array(items?));
    }
    // numbers: int if it parses as i64 and has no float syntax
    let clean = text.replace('_', "");
    if !clean.contains(['.', 'e', 'E']) {
        if let Ok(i) = clean.parse::<i64>() {
            return Ok(TomlValue::Int(i));
        }
    }
    if let Ok(f) = clean.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("cannot parse value '{text}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_typical_config() {
        let doc = TomlDoc::parse(
            r#"
# run configuration
name = "fig1"          # inline comment
[problem]
n = 500
rank = 25
sparsity = 0.05
[dcf]
clients = 10
k_local = 2
eta0 = 0.05
adaptive = true
sizes = [5, 30, 5]
"#,
        )
        .unwrap();
        assert_eq!(doc.get("", "name").unwrap().as_str(), Some("fig1"));
        assert_eq!(doc.get("problem", "n").unwrap().as_usize(), Some(500));
        assert_eq!(doc.get("problem", "sparsity").unwrap().as_float(), Some(0.05));
        assert_eq!(doc.get("dcf", "adaptive").unwrap().as_bool(), Some(true));
        let sizes = doc.get("dcf", "sizes").unwrap().as_array().unwrap();
        assert_eq!(sizes.len(), 3);
        assert_eq!(sizes[1].as_usize(), Some(30));
        assert_eq!(doc.sections(), vec!["", "dcf", "problem"]);
    }

    #[test]
    fn int_float_distinction() {
        let doc = TomlDoc::parse("a = 3\nb = 3.0\nc = 1e-3\nd = 1_000").unwrap();
        assert_eq!(doc.get("", "a").unwrap(), &TomlValue::Int(3));
        assert_eq!(doc.get("", "b").unwrap(), &TomlValue::Float(3.0));
        assert_eq!(doc.get("", "c").unwrap(), &TomlValue::Float(1e-3));
        assert_eq!(doc.get("", "d").unwrap(), &TomlValue::Int(1000));
        // int coerces to float on demand
        assert_eq!(doc.get("", "a").unwrap().as_float(), Some(3.0));
    }

    #[test]
    fn hash_in_string_is_not_comment() {
        let doc = TomlDoc::parse(r##"path = "out#1.csv""##).unwrap();
        assert_eq!(doc.get("", "path").unwrap().as_str(), Some("out#1.csv"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(TomlDoc::parse("[unclosed").is_err());
        assert!(TomlDoc::parse("novalue =").is_err());
        assert!(TomlDoc::parse("= 3").is_err());
        assert!(TomlDoc::parse("x = \"unterminated").is_err());
        assert!(TomlDoc::parse("x = [1, 2").is_err());
        assert!(TomlDoc::parse("x = what").is_err());
    }

    #[test]
    fn rejects_duplicates() {
        assert!(TomlDoc::parse("a = 1\na = 2").is_err());
        // same key in different sections is fine
        assert!(TomlDoc::parse("[x]\na = 1\n[y]\na = 2").is_ok());
    }

    #[test]
    fn keys_listing() {
        let doc = TomlDoc::parse("[s]\nb = 1\na = 2").unwrap();
        let mut keys = doc.keys("s");
        keys.sort_unstable();
        assert_eq!(keys, vec!["a", "b"]);
    }
}
