//! Typed run configuration loaded from TOML files (see `configs/*.toml`
//! for examples). One [`RunConfig`] fully describes a solver run: the
//! problem instance, the algorithm, and (for DCF-PCA) the federation
//! parameters.

pub mod toml;

use std::collections::BTreeSet;
use std::path::Path;

use crate::bail;
use crate::error::{Context, Error, Result};

use crate::algorithms::schedule::Schedule;
use crate::coordinator::driver::{DcfPcaConfig, KernelSpec, PartitionSpec};
use crate::coordinator::privacy::PrivacySpec;
use crate::coordinator::server::FaultPolicy;
use crate::coordinator::Aggregation;
use crate::rpca::problem::ProblemSpec;

use self::toml::TomlDoc;

/// Which algorithm a run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    DcfPca,
    CfPca,
    Apgm,
    Alm,
}

impl Algorithm {
    pub fn parse(s: &str) -> Result<Algorithm> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "dcf-pca" | "dcfpca" | "dcf" => Algorithm::DcfPca,
            "cf-pca" | "cfpca" | "cf" => Algorithm::CfPca,
            "apgm" | "apg" => Algorithm::Apgm,
            "alm" | "ialm" => Algorithm::Alm,
            other => bail!("unknown algorithm '{other}' (dcf-pca|cf-pca|apgm|alm)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::DcfPca => "DCF-PCA",
            Algorithm::CfPca => "CF-PCA",
            Algorithm::Apgm => "APGM",
            Algorithm::Alm => "ALM",
        }
    }
}

/// A complete, validated run description.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub name: String,
    pub algorithm: Algorithm,
    pub problem: ProblemSpec,
    pub problem_seed: u64,
    pub dcf: DcfPcaConfig,
    /// iteration cap for the centralized solvers
    pub max_iters: usize,
    pub tol: f64,
    /// use the PJRT artifact backend for client updates
    pub use_pjrt: bool,
    /// artifacts directory (for use_pjrt)
    pub artifacts_dir: String,
    /// output CSV path for the error curve (optional)
    pub output_csv: Option<String>,
}

impl RunConfig {
    /// Built-in defaults at the paper's n=500 scale.
    pub fn default_run() -> RunConfig {
        let problem = ProblemSpec::paper_default(500);
        RunConfig {
            name: "default".into(),
            algorithm: Algorithm::DcfPca,
            problem,
            problem_seed: 42,
            dcf: DcfPcaConfig::default_for(&problem),
            max_iters: 100,
            tol: 1e-7,
            use_pjrt: false,
            artifacts_dir: "artifacts".into(),
            output_csv: None,
        }
    }

    pub fn from_file(path: impl AsRef<Path>) -> Result<RunConfig> {
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_toml_str(&text)
    }

    pub fn from_toml_str(text: &str) -> Result<RunConfig> {
        let doc = TomlDoc::parse(text)?;
        validate_known_keys(&doc)?;
        let mut cfg = RunConfig::default_run();

        if let Some(v) = doc.get("", "name") {
            cfg.name = v.as_str().context("name must be a string")?.to_string();
        }
        if let Some(v) = doc.get("", "algorithm") {
            cfg.algorithm = Algorithm::parse(v.as_str().context("algorithm must be a string")?)?;
        }

        // [problem]
        let mut spec = cfg.problem;
        if let Some(v) = doc.get("problem", "m") {
            spec.m = v.as_usize().context("problem.m")?;
        }
        if let Some(v) = doc.get("problem", "n") {
            spec.n = v.as_usize().context("problem.n")?;
            if doc.get("problem", "m").is_none() {
                spec.m = spec.n; // square by default
            }
            // paper default shapes track n unless overridden
            if doc.get("problem", "rank").is_none() {
                spec.rank = ((spec.n as f64) * 0.05).round().max(1.0) as usize;
            }
        }
        if let Some(v) = doc.get("problem", "rank") {
            spec.rank = v.as_usize().context("problem.rank")?;
        }
        if let Some(v) = doc.get("problem", "sparsity") {
            spec.sparsity = v.as_float().context("problem.sparsity")?;
        }
        if let Some(v) = doc.get("problem", "seed") {
            cfg.problem_seed = v.as_int().context("problem.seed")? as u64;
        }
        spec.validate().map_err(Error::msg)?;
        cfg.problem = spec;
        cfg.dcf = DcfPcaConfig::default_for(&spec);

        // [solver]
        if let Some(v) = doc.get("solver", "max_iters") {
            cfg.max_iters = v.as_usize().context("solver.max_iters")?;
        }
        if let Some(v) = doc.get("solver", "tol") {
            cfg.tol = v.as_float().context("solver.tol")?;
        }
        if let Some(v) = doc.get("solver", "rank") {
            cfg.dcf.hyper.rank = v.as_usize().context("solver.rank")?;
        }
        if let Some(v) = doc.get("solver", "rho") {
            cfg.dcf.hyper.rho = v.as_float().context("solver.rho")?;
        }
        if let Some(v) = doc.get("solver", "lambda") {
            cfg.dcf.hyper.lambda = v.as_float().context("solver.lambda")?;
        }
        if let Some(v) = doc.get("solver", "inner_sweeps") {
            cfg.dcf.hyper.inner_sweeps = v.as_usize().context("solver.inner_sweeps")?;
        }
        if let Some(v) = doc.get("solver", "polish_sweeps") {
            cfg.dcf.polish_sweeps = v.as_usize().context("solver.polish_sweeps")?;
        }

        // [dcf]
        if let Some(v) = doc.get("dcf", "clients") {
            cfg.dcf.clients = v.as_usize().context("dcf.clients")?;
        }
        if let Some(v) = doc.get("dcf", "rounds") {
            cfg.dcf.rounds = v.as_usize().context("dcf.rounds")?;
        }
        if let Some(v) = doc.get("dcf", "k_local") {
            cfg.dcf.k_local = v.as_usize().context("dcf.k_local")?;
        }
        if let Some(v) = doc.get("dcf", "seed") {
            cfg.dcf.seed = v.as_int().context("dcf.seed")? as u64;
        }
        cfg.dcf.schedule = parse_schedule(&doc, cfg.dcf.k_local, cfg.dcf.rounds)?;
        if let Some(v) = doc.get("dcf", "aggregation") {
            cfg.dcf.aggregation = match v.as_str().context("dcf.aggregation")? {
                "uniform" => Aggregation::Uniform,
                "weighted" => Aggregation::WeightedByCols,
                other => bail!("unknown aggregation '{other}'"),
            };
        }
        if let Some(v) = doc.get("dcf", "fault_policy") {
            cfg.dcf.fault_policy = match v.as_str().context("dcf.fault_policy")? {
                "strict" => FaultPolicy::Strict,
                "skip" | "skip_missing" => FaultPolicy::SkipMissing,
                other => bail!("unknown fault_policy '{other}'"),
            };
        }
        if let Some(v) = doc.get("dcf", "partition_sizes") {
            let sizes: Option<Vec<usize>> =
                v.as_array().context("dcf.partition_sizes")?.iter().map(|x| x.as_usize()).collect();
            cfg.dcf.partition = PartitionSpec::Sizes(sizes.context("partition_sizes must be ints")?);
        }
        if let Some(v) = doc.get("dcf", "private_clients") {
            let ids: Option<BTreeSet<usize>> =
                v.as_array().context("dcf.private_clients")?.iter().map(|x| x.as_usize()).collect();
            cfg.dcf.privacy = PrivacySpec::with_private(ids.context("private_clients must be ints")?);
        }
        if let Some(v) = doc.get("dcf", "err_stop") {
            cfg.dcf.err_stop = Some(v.as_float().context("dcf.err_stop")?);
        }
        if let Some(v) = doc.get("dcf", "compression") {
            cfg.dcf.compression =
                crate::coordinator::Compression::parse(v.as_str().context("dcf.compression")?)?;
        }
        if let Some(v) = doc.get("dcf", "participation") {
            cfg.dcf.participation = v.as_float().context("dcf.participation")?;
        }
        if let Some(v) = doc.get("dcf", "dp_sigma") {
            cfg.dcf.dp_sigma = v.as_float().context("dcf.dp_sigma")?;
        }

        // [runtime]
        if let Some(v) = doc.get("runtime", "use_pjrt") {
            cfg.use_pjrt = v.as_bool().context("runtime.use_pjrt")?;
        }
        if let Some(v) = doc.get("runtime", "artifacts_dir") {
            cfg.artifacts_dir = v.as_str().context("runtime.artifacts_dir")?.to_string();
        }

        // [output]
        if let Some(v) = doc.get("output", "csv") {
            cfg.output_csv = Some(v.as_str().context("output.csv")?.to_string());
        }

        cfg.dcf.kernel = KernelSpec::Native; // PJRT kernel attached by the launcher
        Ok(cfg)
    }
}

fn parse_schedule(doc: &TomlDoc, k_local: usize, rounds: usize) -> Result<Schedule> {
    let kind = doc
        .get("dcf", "schedule")
        .map(|v| v.as_str().context("dcf.schedule must be a string"))
        .transpose()?
        .unwrap_or("adaptive");
    let eta0 = doc
        .get("dcf", "eta0")
        .map(|v| v.as_float().context("dcf.eta0"))
        .transpose()?
        .unwrap_or(match kind {
            "adaptive" => 0.9,
            _ => 0.05,
        });
    Ok(match kind {
        "adaptive" => Schedule::Adaptive { eta0 },
        "const" => Schedule::Const { eta: eta0 },
        "inv_t" | "decay" => Schedule::InvT { eta0, t0: 10.0 },
        "inv_sqrt_kt" => Schedule::InvSqrtKT { c: eta0, k_local, rounds },
        other => bail!("unknown schedule '{other}'"),
    })
}

/// Reject typo'd keys instead of silently ignoring them.
fn validate_known_keys(doc: &TomlDoc) -> Result<()> {
    const KNOWN: &[(&str, &[&str])] = &[
        ("", &["name", "algorithm"]),
        ("problem", &["m", "n", "rank", "sparsity", "seed"]),
        ("solver", &["max_iters", "tol", "rank", "rho", "lambda", "inner_sweeps", "polish_sweeps"]),
        (
            "dcf",
            &[
                "clients", "rounds", "k_local", "seed", "schedule", "eta0", "aggregation",
                "fault_policy", "partition_sizes", "private_clients", "err_stop",
                "compression", "participation", "dp_sigma",
            ],
        ),
        ("runtime", &["use_pjrt", "artifacts_dir"]),
        ("output", &["csv"]),
    ];
    for section in doc.sections() {
        let allowed = KNOWN
            .iter()
            .find(|(s, _)| *s == section)
            .map(|(_, ks)| *ks)
            .with_context(|| format!("unknown config section [{section}]"))?;
        for key in doc.keys(section) {
            if !allowed.contains(&key) {
                bail!("unknown config key '{key}' in section [{section}]");
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_document_parses() {
        let cfg = RunConfig::from_toml_str(
            r#"
name = "fig4-k10"
algorithm = "dcf-pca"
[problem]
n = 500
sparsity = 0.05
seed = 7
[dcf]
clients = 10
rounds = 50
k_local = 10
schedule = "const"
eta0 = 0.01
private_clients = [0, 3]
[output]
csv = "out/fig4.csv"
"#,
        )
        .unwrap();
        assert_eq!(cfg.name, "fig4-k10");
        assert_eq!(cfg.problem.n, 500);
        assert_eq!(cfg.problem.rank, 25); // 0.05n default
        assert_eq!(cfg.dcf.k_local, 10);
        assert_eq!(cfg.dcf.schedule, Schedule::Const { eta: 0.01 });
        assert!(cfg.dcf.privacy.is_private(0));
        assert!(cfg.dcf.privacy.is_public(1));
        assert_eq!(cfg.output_csv.as_deref(), Some("out/fig4.csv"));
    }

    #[test]
    fn unknown_keys_rejected() {
        assert!(RunConfig::from_toml_str("[problem]\nn = 100\nbogus = 1").is_err());
        assert!(RunConfig::from_toml_str("[bogus_section]\nx = 1").is_err());
    }

    #[test]
    fn algorithm_aliases() {
        assert_eq!(Algorithm::parse("DCF-PCA").unwrap(), Algorithm::DcfPca);
        assert_eq!(Algorithm::parse("ialm").unwrap(), Algorithm::Alm);
        assert!(Algorithm::parse("what").is_err());
    }

    #[test]
    fn invalid_problem_rejected() {
        assert!(RunConfig::from_toml_str("[problem]\nn = 10\nrank = 99").is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let cfg = RunConfig::default_run();
        assert_eq!(cfg.problem.n, 500);
        assert_eq!(cfg.dcf.clients, 10);
        assert!(cfg.dcf.hyper.satisfies_theorem2(cfg.problem.m, cfg.problem.n));
    }
}
