//! # DCF-PCA — Distributed Robust Principal Component Analysis
//!
//! Reproduction of *"Distributed Robust Principal Component Analysis"*
//! (Wenda Chu, CS.DC 2022): the DCF-PCA consensus-factorization algorithm,
//! its centralized counterpart CF-PCA, the APGM/ALM convex-relaxation
//! baselines, and every substrate they need, in a three-layer
//! rust + JAX + Pallas architecture:
//!
//! - **L3 (this crate)** — the federated coordinator: server round loop,
//!   client workers, transport with byte accounting, FedAvg aggregation,
//!   privacy sets, schedules ([`coordinator`]).
//! - **L2/L1 (python, build-time only)** — the client local update as a JAX
//!   function calling Pallas kernels, AOT-lowered to HLO text artifacts.
//! - **Runtime** — [`runtime`] loads `artifacts/*.hlo.txt` via the PJRT C
//!   API (`xla` crate) and executes them from the rust hot path; a
//!   bit-compatible pure-rust `Native` backend is the default and the
//!   parity reference.

pub mod algorithms;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod experiments;
pub mod linalg;
pub mod rng;
pub mod rpca;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod testing;
pub mod util;

/// Compatibility alias for the vendored error substrate (`src/error.rs`)
/// under the name external callers knew from the `anyhow` crate:
/// `dcf_pca::anyhow::Result`, `dcf_pca::anyhow::Context`, … The macros
/// live at the crate root (`dcf_pca::anyhow!`, `dcf_pca::bail!`,
/// `dcf_pca::ensure!`).
pub mod anyhow {
    pub use crate::error::{Context, Error, Result};
}

pub use data::DataSource;
pub use linalg::Mat;
pub use linalg::Workspace;

/// Thread-local allocation counter used by the zero-allocation hot-path
/// tests: counts heap allocations made on the calling thread between
/// [`alloc_counter::measure`] boundaries. Installed as the global
/// allocator only in the lib's own test builds.
#[cfg(test)]
pub(crate) mod alloc_counter {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::cell::Cell;

    std::thread_local! {
        static ALLOCS: Cell<u64> = const { Cell::new(0) };
        static ARMED: Cell<bool> = const { Cell::new(false) };
    }

    pub struct CountingAllocator;

    fn bump() {
        // try_with: never panic inside the allocator (TLS may be mid-teardown)
        let _ = ARMED.try_with(|armed| {
            if armed.get() {
                let _ = ALLOCS.try_with(|c| c.set(c.get() + 1));
            }
        });
    }

    unsafe impl GlobalAlloc for CountingAllocator {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            bump();
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            bump();
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            bump();
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    /// Run `f` with allocation counting armed on this thread; returns
    /// `(f(), allocations_made)`.
    pub fn measure<T>(f: impl FnOnce() -> T) -> (T, u64) {
        ARMED.with(|armed| armed.set(true));
        ALLOCS.with(|c| c.set(0));
        let out = f();
        let count = ALLOCS.with(|c| c.get());
        ARMED.with(|armed| armed.set(false));
        (out, count)
    }
}

#[cfg(test)]
#[global_allocator]
static COUNTING_ALLOCATOR: alloc_counter::CountingAllocator = alloc_counter::CountingAllocator;
