//! # DCF-PCA — Distributed Robust Principal Component Analysis
//!
//! Reproduction of *"Distributed Robust Principal Component Analysis"*
//! (Wenda Chu, CS.DC 2022): the DCF-PCA consensus-factorization algorithm,
//! its centralized counterpart CF-PCA, the APGM/ALM convex-relaxation
//! baselines, and every substrate they need, in a three-layer
//! rust + JAX + Pallas architecture:
//!
//! - **L3 (this crate)** — the federated coordinator: server round loop,
//!   client workers, transport with byte accounting, FedAvg aggregation,
//!   privacy sets, schedules ([`coordinator`]).
//! - **L2/L1 (python, build-time only)** — the client local update as a JAX
//!   function calling Pallas kernels, AOT-lowered to HLO text artifacts.
//! - **Runtime** — [`runtime`] loads `artifacts/*.hlo.txt` via the PJRT C
//!   API (`xla` crate) and executes them from the rust hot path; a
//!   bit-compatible pure-rust `Native` backend is the default and the
//!   parity reference.

pub mod algorithms;
pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod experiments;
pub mod linalg;
pub mod rng;
pub mod rpca;
pub mod runtime;
pub mod telemetry;
pub mod testing;
pub mod util;

pub use linalg::Mat;
