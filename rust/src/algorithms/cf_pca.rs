//! CF-PCA — the centralized consensus-factorization baseline (paper §4.2).
//!
//! Identical math to DCF-PCA with a single client owning all of M:
//! per outer iteration, solve the inner problem (Eq. 7) for (V, S) given U,
//! then one gradient step on U. The paper notes CF-PCA "makes use of a
//! larger learning rate" than its distributed counterpart — our default is
//! the adaptive curvature-normalized schedule with η₀ close to 1.

use std::time::Instant;

use crate::linalg::{matmul_nt_into, Mat, Workspace};
use crate::rpca::problem::RpcaProblem;

use super::factor::{
    inner_objective, inner_solve, lipschitz_estimate, polish_sweep, u_gradient_into, ClientState,
    FactorHyper,
};
use super::schedule::Schedule;
use super::traits::{IterRecord, RpcaSolver, SolveResult, StopCriteria};

/// Centralized factorization solver.
#[derive(Clone, Debug)]
pub struct CfPca {
    pub hyper: FactorHyper,
    pub schedule: Schedule,
    pub stop: StopCriteria,
    /// RNG seed for the U⁰ init
    pub seed: u64,
    /// debias polish sweeps applied to (V, S) after the outer loop
    /// (U stays fixed — same semantics as the per-client polish in
    /// DCF-PCA); 0 disables
    pub polish_sweeps: usize,
}

impl CfPca {
    /// Defaults for an m×n problem with factor width `rank`.
    pub fn new(m: usize, n: usize, rank: usize) -> Self {
        CfPca {
            hyper: FactorHyper::default_for(m, n, rank),
            schedule: Schedule::Adaptive { eta0: 0.9 },
            stop: StopCriteria::default(),
            seed: 0xCF,
            polish_sweeps: 3,
        }
    }

    pub fn with_schedule(mut self, s: Schedule) -> Self {
        self.schedule = s;
        self
    }

    pub fn with_stop(mut self, stop: StopCriteria) -> Self {
        self.stop = stop;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

impl RpcaSolver for CfPca {
    fn name(&self) -> &'static str {
        "CF-PCA"
    }

    fn solve(&self, observed: &Mat, truth: Option<&RpcaProblem>) -> SolveResult {
        let (m, n) = observed.shape();
        let start = Instant::now();
        let mut rng = crate::rng::Pcg64::new(self.seed);
        let mut u = Mat::gaussian(m, self.hyper.rank, &mut rng);
        let mut state = ClientState::zeros(m, n, self.hyper.rank);
        // one workspace for the whole run — the outer loop's linalg reuses
        // these buffers instead of allocating per iteration; panels fan
        // out over the process-wide pool (CLI `--threads`)
        let pool = crate::runtime::pool::global();
        let mut ws = Workspace::new(m, n, self.hyper.rank);
        // telemetry buffers for the L = U·Vᵀ convergence check
        let mut l = Mat::zeros(m, n);
        let mut prev_l = Mat::zeros(m, n);
        let mut have_prev = false;
        let mut history = Vec::with_capacity(self.stop.max_iters);
        let mut converged = false;
        let mut iters = 0;

        for t in 0..self.stop.max_iters {
            inner_solve(&u, observed, &mut state, &self.hyper, pool, &mut ws)
                .expect("resident panel fetch cannot fail");
            let lip = lipschitz_estimate(&state, &self.hyper, &mut ws);
            let eta = self.schedule.eta(t, lip);
            u_gradient_into(&u, observed, &state, &self.hyper, 1.0, pool, &mut ws)
                .expect("resident panel fetch cannot fail");
            let gn = ws.grad.frob_norm();
            u.axpy(-eta, &ws.grad);
            iters = t + 1;

            matmul_nt_into(&mut l, &u, &state.v);
            let err = truth.map(|p| crate::rpca::metrics::problem_error(p, &l, &state.s));
            let obj =
                inner_objective(&u, observed, &state, &self.hyper) + 0.5 * self.hyper.rho * u.frob_norm_sq();
            history.push(IterRecord {
                iter: t,
                err,
                objective: obj,
                grad_norm: gn,
                elapsed: start.elapsed().as_secs_f64(),
            });

            if have_prev {
                // one-pass relative-change check (no difference temporary)
                let mut num = 0.0;
                let mut den = 0.0;
                for (cur, prev) in l.as_slice().iter().zip(prev_l.as_slice()) {
                    let d = cur - prev;
                    num += d * d;
                    den += prev * prev;
                }
                let delta = num.sqrt() / den.sqrt().max(1e-300);
                if delta < self.stop.tol {
                    converged = true;
                    break;
                }
            }
            prev_l.copy_from(&l);
            have_prev = true;
        }

        // final inner solve so (V,S) correspond to the final U
        inner_solve(&u, observed, &mut state, &self.hyper, pool, &mut ws)
            .expect("resident panel fetch cannot fail");
        for _ in 0..self.polish_sweeps {
            polish_sweep(&u, observed, &mut state, &self.hyper, pool, &mut ws)
                .expect("resident panel fetch cannot fail");
        }
        matmul_nt_into(&mut l, &u, &state.v);
        let final_error = truth.map(|p| crate::rpca::metrics::problem_error(p, &l, &state.s));
        SolveResult {
            l,
            s: state.s,
            history,
            iterations: iters,
            converged,
            wall: start.elapsed(),
            final_error,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpca::problem::ProblemSpec;

    #[test]
    fn recovers_small_instance() {
        let p = ProblemSpec::square(60, 3, 0.05).generate(42);
        let solver = CfPca::new(60, 60, 3).with_stop(StopCriteria { max_iters: 80, tol: 1e-9 });
        let res = solver.solve(&p.observed, Some(&p));
        let err = res.final_error.unwrap();
        assert!(err < 1e-3, "relative error {err}");
    }

    #[test]
    fn error_decreases_over_run() {
        let p = ProblemSpec::square(50, 3, 0.05).generate(43);
        let solver = CfPca::new(50, 50, 3).with_stop(StopCriteria { max_iters: 40, tol: 0.0 });
        let res = solver.solve(&p.observed, Some(&p));
        let curve = res.error_curve();
        let first = curve.first().unwrap().1;
        let last = curve.last().unwrap().1;
        assert!(last < first * 0.1, "first {first} last {last}");
    }

    #[test]
    fn upper_bound_rank_still_recovers() {
        // p = 2r (paper §2.2 "Problems with Unknown Exact Rank").
        // The paper's own Table 1 reports ~3–11% relative σ error in this
        // regime (recovery is approximate, with early stopping at ≤50
        // iterations) — we check the same metric at the paper's Fig. 3
        // scale n=200, r=0.05n, p=2r.
        let p = ProblemSpec::square(200, 10, 0.05).generate(44);
        let mut solver = CfPca::new(200, 200, 20); // p = 2r
        solver.stop = StopCriteria { max_iters: 50, tol: 1e-9 };
        let res = solver.solve(&p.observed, Some(&p));
        let sv = crate::rpca::metrics::singular_value_error(&res.l, &p.l0, 10);
        assert!(sv.relative < 0.1, "relative σ error with p=2r: {}", sv.relative);
        assert!(sv.tail_ratio < 0.2, "σ_{{r+1}}/σ_r = {}", sv.tail_ratio);
    }

    #[test]
    fn deterministic_given_seed() {
        let p = ProblemSpec::square(30, 2, 0.05).generate(45);
        let solver = CfPca::new(30, 30, 2).with_stop(StopCriteria { max_iters: 10, tol: 0.0 });
        let a = solver.solve(&p.observed, None);
        let b = solver.solve(&p.observed, None);
        assert_eq!(a.l, b.l);
        assert_eq!(a.s, b.s);
    }
}
