//! RPCA solver implementations: the consensus-factorization machinery
//! shared by CF-PCA/DCF-PCA, the two SVD-based convex baselines from the
//! paper's Fig. 1 (APGM, ALM), and the common solver interface.

pub mod alm;
pub mod apgm;
pub mod cf_pca;
pub mod factor;
pub mod schedule;
pub mod traits;

pub use alm::Alm;
pub use apgm::Apgm;
pub use cf_pca::CfPca;
pub use factor::{ClientState, FactorHyper};
pub use schedule::Schedule;
pub use traits::{IterRecord, RpcaSolver, SolveResult, StopCriteria};
