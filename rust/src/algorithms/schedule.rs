//! Learning-rate schedules for the U gradient steps (paper §2.2 / §4.2).
//!
//! The paper uses a decaying rate η = O(η₀/t) for the main experiments and
//! η = c/√(KT) for the Theorem 1 guarantee; we additionally provide an
//! adaptive curvature-normalized rate (η₀ / L̂ with L̂ from
//! [`crate::algorithms::factor::lipschitz_estimate`]) that makes runs
//! robust across problem scales without hand-tuning.

/// Step-size policy for U updates.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Schedule {
    /// fixed η
    Const { eta: f64 },
    /// η₀ / (1 + t/t₀) — the paper's decaying schedule
    InvT { eta0: f64, t0: f64 },
    /// c / √(K·T) — Theorem 1's rate (fixed over the whole run)
    InvSqrtKT { c: f64, k_local: usize, rounds: usize },
    /// η₀ / L̂(t) where L̂ is the current curvature estimate (σ_max(VᵀV)+ρ);
    /// scale-free variant used by the defaults
    Adaptive { eta0: f64 },
}

impl Schedule {
    /// Step size at outer iteration `t` (0-based). `lipschitz` is the
    /// current curvature estimate (used only by `Adaptive`).
    pub fn eta(&self, t: usize, lipschitz: f64) -> f64 {
        match *self {
            Schedule::Const { eta } => eta,
            Schedule::InvT { eta0, t0 } => eta0 / (1.0 + t as f64 / t0),
            Schedule::InvSqrtKT { c, k_local, rounds } => {
                c / ((k_local * rounds.max(1)) as f64).sqrt()
            }
            Schedule::Adaptive { eta0 } => eta0 / lipschitz.max(1e-12),
        }
    }

    /// The paper's Fig. 1 setting: decaying from η₀.
    pub fn paper_decay(eta0: f64) -> Schedule {
        Schedule::InvT { eta0, t0: 10.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_is_constant() {
        let s = Schedule::Const { eta: 0.3 };
        assert_eq!(s.eta(0, 1.0), 0.3);
        assert_eq!(s.eta(99, 123.0), 0.3);
    }

    #[test]
    fn inv_t_decays() {
        let s = Schedule::InvT { eta0: 1.0, t0: 10.0 };
        assert!(s.eta(0, 1.0) > s.eta(10, 1.0));
        assert!((s.eta(10, 1.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn inv_sqrt_kt_matches_formula() {
        let s = Schedule::InvSqrtKT { c: 2.0, k_local: 4, rounds: 25 };
        assert!((s.eta(7, 1.0) - 0.2).abs() < 1e-12); // 2/√100
    }

    #[test]
    fn adaptive_divides_by_curvature() {
        let s = Schedule::Adaptive { eta0: 0.5 };
        assert!((s.eta(0, 10.0) - 0.05).abs() < 1e-12);
        // guards against zero curvature
        assert!(s.eta(0, 0.0).is_finite());
    }
}
