//! ALM baseline — inexact augmented Lagrange multiplier method for the
//! convex RPCA program (paper Eq. 2), following Lin/Goldfarb-Ma
//! [paper ref 10]:
//!
//!   min ‖L‖_* + λ‖S‖₁  s.t.  L + S = M
//!
//! with the augmented Lagrangian
//!   ‖L‖_* + λ‖S‖₁ + ⟨Y, M−L−S⟩ + μ/2‖M−L−S‖²_F.
//! Per iteration: one SVT for L, one shrink for S, a dual ascent on Y, and
//! geometric growth of μ. Typically converges to exact recovery in a few
//! tens of iterations — the strongest centralized baseline in Fig. 1.

use std::time::Instant;

use crate::linalg::{rsvd_svt, svt, Mat};
use crate::rpca::problem::RpcaProblem;
use crate::runtime::pool::BandSlice;

use super::apgm::spectral_norm;
use super::traits::{IterRecord, RpcaSolver, SolveResult, StopCriteria};

const SVD_EXACT_LIMIT: usize = 160;

/// Inexact-ALM RPCA solver.
#[derive(Clone, Debug)]
pub struct Alm {
    /// ℓ1 weight; default 1/√max(m,n)
    pub lambda: Option<f64>,
    /// penalty growth factor ρ_μ
    pub mu_growth: f64,
    pub stop: StopCriteria,
    pub svt_rank_hint: usize,
}

impl Alm {
    pub fn new() -> Self {
        Alm {
            lambda: None,
            mu_growth: 1.6,
            stop: StopCriteria { max_iters: 120, tol: 1e-7 },
            svt_rank_hint: 16,
        }
    }

    pub fn with_stop(mut self, stop: StopCriteria) -> Self {
        self.stop = stop;
        self
    }
}

impl Default for Alm {
    fn default() -> Self {
        Self::new()
    }
}

impl RpcaSolver for Alm {
    fn name(&self) -> &'static str {
        "ALM"
    }

    fn solve(&self, observed: &Mat, truth: Option<&RpcaProblem>) -> SolveResult {
        let (m, n) = observed.shape();
        let start = Instant::now();
        let lambda = self.lambda.unwrap_or(1.0 / (m.max(n) as f64).sqrt());
        let norm2 = spectral_norm(observed, 30);
        let norm_inf = observed.max_abs();
        // dual init Y = M / J(M), J(M) = max(‖M‖₂, ‖M‖_∞/λ)  (Lin et al.)
        let j_m = norm2.max(norm_inf / lambda).max(1e-300);
        let mut y = observed.scale(1.0 / j_m);
        let mut mu = 1.25 / norm2.max(1e-300);

        let mut l = Mat::zeros(m, n);
        let mut s = Mat::zeros(m, n);
        // reused SVT-input buffer: the only per-iteration full-size
        // temporaries left are inside the SVD itself
        let mut target = Mat::zeros(m, n);
        let mut rank_hint = self.svt_rank_hint;
        let m_norm = observed.frob_norm().max(1e-300);

        let mut history = Vec::new();
        let mut converged = false;
        let mut iters = 0;
        // fused elementwise passes fan across the process-wide pool in
        // fixed bands (deterministic at any `--threads`)
        let pool = crate::runtime::pool::global();

        for k in 0..self.stop.max_iters {
            let inv_mu = 1.0 / mu;
            // L = SVT_{1/μ}(M − S + Y/μ), target fused in one banded pass
            {
                let tv = BandSlice::new(target.as_mut_slice());
                let md = observed.as_slice();
                let sd = s.as_slice();
                let yd = y.as_slice();
                pool.run_bands(md.len(), &|_, lo, hi| {
                    // SAFETY: bands are disjoint ranges
                    let td = unsafe { tv.range(lo, hi) };
                    for (t, i) in td.iter_mut().zip(lo..hi) {
                        *t = md[i] - sd[i] + yd[i] * inv_mu;
                    }
                    0.0
                });
            }
            let min_dim = m.min(n);
            let (l_new, rank) = if min_dim <= SVD_EXACT_LIMIT {
                svt(&target, 1.0 / mu)
            } else {
                let mut hint = rank_hint.min(min_dim);
                loop {
                    let (out, r) = rsvd_svt(&target, 1.0 / mu, hint, 0xA1 + k as u64);
                    if r < hint || hint == min_dim {
                        rank_hint = (r + 5).max(hint / 2).min(min_dim);
                        break (out, r);
                    }
                    hint = (hint * 2).min(min_dim);
                }
            };
            l = l_new;
            // S = shrink_{λ/μ}(M − L + Y/μ), fused directly into S
            {
                let sv = BandSlice::new(s.as_mut_slice());
                let md = observed.as_slice();
                let ld = l.as_slice();
                let yd = y.as_slice();
                let thresh = lambda * inv_mu;
                pool.run_bands(md.len(), &|_, lo, hi| {
                    // SAFETY: bands are disjoint ranges
                    let sd = unsafe { sv.range(lo, hi) };
                    crate::linalg::shrink_dual_into(
                        sd,
                        &md[lo..hi],
                        &ld[lo..hi],
                        &yd[lo..hi],
                        inv_mu,
                        thresh,
                    );
                    0.0
                });
            }
            // dual ascent Y += μ(M − L − S), feasibility norm in the same
            // pass (band partials summed in band order — deterministic)
            let infeas_sq = {
                let yv = BandSlice::new(y.as_mut_slice());
                let md = observed.as_slice();
                let ld = l.as_slice();
                let sd = s.as_slice();
                pool.run_bands(md.len(), &|_, lo, hi| {
                    // SAFETY: bands are disjoint ranges
                    let yd = unsafe { yv.range(lo, hi) };
                    let mut acc = 0.0;
                    for (yx, i) in yd.iter_mut().zip(lo..hi) {
                        let r = md[i] - ld[i] - sd[i];
                        acc += r * r;
                        *yx += mu * r;
                    }
                    acc
                })
            };
            mu *= self.mu_growth;
            iters = k + 1;

            let crit = infeas_sq.sqrt() / m_norm;
            let err = truth.map(|p| crate::rpca::metrics::problem_error(p, &l, &s));
            history.push(IterRecord {
                iter: k,
                err,
                objective: rank as f64,
                grad_norm: crit,
                elapsed: start.elapsed().as_secs_f64(),
            });
            if crit < self.stop.tol {
                converged = true;
                break;
            }
        }

        let final_error = truth.map(|p| crate::rpca::metrics::problem_error(p, &l, &s));
        SolveResult {
            l,
            s,
            history,
            iterations: iters,
            converged,
            wall: start.elapsed(),
            final_error,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpca::problem::ProblemSpec;

    #[test]
    fn recovers_small_instance_exactly() {
        let p = ProblemSpec::square(60, 3, 0.05).generate(48);
        let solver = Alm::new();
        let res = solver.solve(&p.observed, Some(&p));
        let err = res.final_error.unwrap();
        assert!(err < 1e-6, "relative error {err}");
        assert!(res.converged, "ALM should hit its feasibility criterion");
    }

    #[test]
    fn handles_higher_corruption() {
        let p = ProblemSpec::square(80, 4, 0.2).generate(49);
        let res = Alm::new().solve(&p.observed, Some(&p));
        let err = res.final_error.unwrap();
        assert!(err < 1e-4, "relative error at s=0.2: {err}");
    }

    #[test]
    fn feasibility_residual_decreases() {
        let p = ProblemSpec::square(40, 2, 0.05).generate(50);
        let res = Alm::new()
            .with_stop(StopCriteria { max_iters: 30, tol: 0.0 })
            .solve(&p.observed, Some(&p));
        let first = res.history.first().unwrap().grad_norm;
        let last = res.history.last().unwrap().grad_norm;
        assert!(last < first * 1e-3, "first {first} last {last}");
    }
}
