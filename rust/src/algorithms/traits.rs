//! Common interface for all RPCA solvers (Fig. 1 compares four of them).

use std::time::Duration;

use crate::linalg::Mat;
use crate::rpca::problem::RpcaProblem;

/// One point of a convergence curve.
#[derive(Clone, Copy, Debug)]
pub struct IterRecord {
    /// outer iteration (communication round for DCF-PCA)
    pub iter: usize,
    /// relative recovery error (Eq. 30) against ground truth, if available
    pub err: Option<f64>,
    /// solver objective value (algorithm-specific; NaN if not tracked)
    pub objective: f64,
    /// ‖∇_U g‖_F for factorization methods (Theorem 1's quantity), else NaN
    pub grad_norm: f64,
    /// wall-clock seconds since solve start
    pub elapsed: f64,
}

/// Final output of a solver run.
#[derive(Clone, Debug)]
pub struct SolveResult {
    /// recovered low-rank component
    pub l: Mat,
    /// recovered sparse component
    pub s: Mat,
    /// per-iteration telemetry (the data behind Fig. 1 / Fig. 4 curves)
    pub history: Vec<IterRecord>,
    /// iterations actually executed
    pub iterations: usize,
    /// true if the stopping criterion (not the iteration cap) fired
    pub converged: bool,
    /// total wall time
    pub wall: Duration,
    /// final Eq. 30 error if ground truth was supplied
    pub final_error: Option<f64>,
}

impl SolveResult {
    /// Error series for plotting (iter, err).
    pub fn error_curve(&self) -> Vec<(usize, f64)> {
        self.history
            .iter()
            .filter_map(|r| r.err.map(|e| (r.iter, e)))
            .collect()
    }
}

/// Stopping criteria shared by all solvers.
#[derive(Clone, Copy, Debug)]
pub struct StopCriteria {
    /// iteration cap
    pub max_iters: usize,
    /// stop when the relative change of (L, S) between iterations falls
    /// below this (or the algorithm's native residual criterion)
    pub tol: f64,
}

impl Default for StopCriteria {
    fn default() -> Self {
        StopCriteria { max_iters: 100, tol: 1e-7 }
    }
}

/// An RPCA solver: recovers (L, S) from an observed matrix. When the
/// problem's ground truth is supplied, per-iteration Eq. 30 errors are
/// recorded in the history.
pub trait RpcaSolver {
    fn name(&self) -> &'static str;

    /// Solve for (L, S). `truth` enables per-iteration error tracking.
    fn solve(&self, observed: &Mat, truth: Option<&RpcaProblem>) -> SolveResult;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn error_curve_filters_missing() {
        let r = SolveResult {
            l: Mat::zeros(1, 1),
            s: Mat::zeros(1, 1),
            history: vec![
                IterRecord { iter: 0, err: Some(1.0), objective: 0.0, grad_norm: 0.0, elapsed: 0.0 },
                IterRecord { iter: 1, err: None, objective: 0.0, grad_norm: 0.0, elapsed: 0.1 },
                IterRecord { iter: 2, err: Some(0.5), objective: 0.0, grad_norm: 0.0, elapsed: 0.2 },
            ],
            iterations: 3,
            converged: false,
            wall: Duration::from_secs(1),
            final_error: Some(0.5),
        };
        assert_eq!(r.error_curve(), vec![(0, 1.0), (2, 0.5)]);
    }
}
