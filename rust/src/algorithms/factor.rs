//! Shared consensus-factorization machinery (paper §2.2).
//!
//! Both the centralized CF-PCA solver and every DCF-PCA client iterate the
//! same two moves on a column block `M_i`:
//!
//! 1. **Inner solve** (Eq. 7): minimize over `(V_i, S_i)` with `U` fixed.
//!    We alternate the two *exact* block updates that characterize the
//!    optimum —
//!    `V_i = (M_i − S_i)ᵀ U (UᵀU + ρI)^{-1}`  (Eq. 15, ridge solve) and
//!    `S_i = shrink_λ(M_i − U V_iᵀ)`           (Eq. 16) —
//!    `J` times. The inner objective is ρ-strongly convex (Lemma 1), each
//!    alternation is an exact coordinate minimization, so the inner
//!    objective descends monotonically (property-tested below).
//! 2. **U gradient step** (Eq. 8):
//!    `U ← U − η ∇_U L_i`,
//!    `∇_U L_i = (U V_iᵀ + S_i − M_i) V_i + ρ (n_i/n) U` (Lemma 2).
//!
//! Every function here borrows a [`Workspace`] sized for the block
//! (`(m, n_i, p)`) instead of allocating temporaries: the inner sweep and
//! the gradient run J × K × T times per DCF-PCA run, and on that path
//! steady-state heap traffic is zero (asserted by the counting-allocator
//! test in `coordinator::kernel`).
//!
//! This module is the native (f64) twin of the AOT-compiled JAX/Pallas
//! `client_update` artifact; `runtime::executor` checks the two against
//! each other.

use crate::linalg::{
    gram_into, matmul_into, matmul_nt, matmul_nt_into, matmul_tn_into, matvec_into, residual_into,
    residual_shrink_into, ridge_solve_v_into, sub_into, Mat, Workspace,
};

/// Hyperparameters of the factorized objective (paper Eq. 4).
#[derive(Clone, Copy, Debug)]
pub struct FactorHyper {
    /// factorization width p (≥ true rank r; = r for exact-rank runs)
    pub rank: usize,
    /// ridge weight ρ on ‖U‖²_F and ‖V‖²_F
    pub rho: f64,
    /// ℓ1 weight λ on S
    pub lambda: f64,
    /// inner alternation sweeps J per local iteration
    pub inner_sweeps: usize,
}

impl FactorHyper {
    /// Defaults that recover the paper's §4 synthetic instances:
    /// λ at the low-rank entry scale (≈√r — entries of L₀ are N(0, r)) and
    /// far below the spike scale √(mn); ρ small. The soft-threshold bias
    /// on the support is λ per entry, giving an error floor of
    /// `s·mn·λ² / (‖L₀‖² + ‖S₀‖²)` — with λ = √r that is ~1e-4 relative,
    /// matching the floors visible in the paper's Fig. 1; the final
    /// [`polish_sweep`] debias removes it. Satisfies Theorem 2
    /// (ρ² ≤ λ²·mn).
    pub fn default_for(m: usize, n: usize, rank: usize) -> Self {
        let lambda = (rank as f64).sqrt().max(1.0);
        let rho = 1e-2;
        debug_assert!(rho * rho <= lambda * lambda * (m * n) as f64);
        FactorHyper { rank, rho, lambda, inner_sweeps: 3 }
    }

    /// Theorem 2's necessary condition for exact recovery: ρ² ≤ λ²·m·n.
    pub fn satisfies_theorem2(&self, m: usize, n: usize) -> bool {
        self.rho * self.rho <= self.lambda * self.lambda * (m as f64) * (n as f64)
    }
}

/// Mutable per-client state: the right factor and sparse component for one
/// column block. `V` is n_i×p, `S` is m×n_i. Persisted across rounds
/// (warm start, per Algorithm 1: "set V_i^(0), S_i^(0) … from the last epoch").
#[derive(Clone, Debug)]
pub struct ClientState {
    pub v: Mat,
    pub s: Mat,
}

impl ClientState {
    /// Cold start: V = 0, S = 0. (The paper randomizes V, but the first
    /// inner sweep solves V exactly given S, which makes the init
    /// irrelevant for J ≥ 1; zeros keep the artifact path deterministic.)
    pub fn zeros(m: usize, n_i: usize, rank: usize) -> Self {
        ClientState { v: Mat::zeros(n_i, rank), s: Mat::zeros(m, n_i) }
    }
}

/// One exact alternation sweep of the inner problem (Eqs. 15 + 16),
/// entirely inside `ws` — no allocation.
pub fn inner_sweep(
    u: &Mat,
    m_block: &Mat,
    state: &mut ClientState,
    hyper: &FactorHyper,
    ws: &mut Workspace,
) {
    ws.assert_shape(m_block.rows(), m_block.cols(), hyper.rank);
    // V ← (M − S)ᵀ U (UᵀU + ρI)^{-1}
    gram_into(&mut ws.gram, u);
    sub_into(&mut ws.resid, m_block, &state.s); // M − S
    matmul_tn_into(&mut ws.rhs, u, &ws.resid); // r×n_i
    ridge_solve_v_into(&mut state.v, &ws.gram, &ws.rhs, hyper.rho, &mut ws.chol, &mut ws.sol);
    // S ← shrink_λ(M − U Vᵀ)
    matmul_nt_into(&mut ws.resid, u, &state.v); // U·Vᵀ, reusing the residual buffer
    residual_shrink_into(&mut state.s, m_block, &ws.resid, hyper.lambda);
}

/// Solve the inner problem (Eq. 7) to tolerance by J alternation sweeps.
pub fn inner_solve(
    u: &Mat,
    m_block: &Mat,
    state: &mut ClientState,
    hyper: &FactorHyper,
    ws: &mut Workspace,
) {
    for _ in 0..hyper.inner_sweeps {
        inner_sweep(u, m_block, state, hyper, ws);
    }
}

/// Inner objective value (Eq. 7's argument):
/// `1/2‖U Vᵀ + S − M‖²_F + ρ/2‖V‖²_F + λ‖S‖₁`.
/// Telemetry-only (tests, per-iteration logging) — allocates.
pub fn inner_objective(u: &Mat, m_block: &Mat, state: &ClientState, hyper: &FactorHyper) -> f64 {
    let uv = matmul_nt(u, &state.v);
    let fit = &(&uv + &state.s) - m_block;
    0.5 * fit.frob_norm_sq()
        + 0.5 * hyper.rho * state.v.frob_norm_sq()
        + hyper.lambda * crate::linalg::l1_norm(&state.s)
}

/// Local objective L_i (Eq. 11) = inner objective + ρ/2·(n_i/n)‖U‖²_F.
pub fn local_objective(
    u: &Mat,
    m_block: &Mat,
    state: &ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
) -> f64 {
    inner_objective(u, m_block, state, hyper) + 0.5 * hyper.rho * n_frac * u.frob_norm_sq()
}

/// ∇_U L_i (Lemma 2): `(U Vᵀ + S − M) V + ρ (n_i/n) U`, written into
/// `ws.grad` (no allocation; the residual is fused into one pass).
/// `n_frac` is n_i/n (1.0 for the centralized solver).
pub fn u_gradient_into(
    u: &Mat,
    m_block: &Mat,
    state: &ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
    ws: &mut Workspace,
) {
    ws.assert_shape(m_block.rows(), m_block.cols(), hyper.rank);
    residual_into(&mut ws.resid, u, &state.v, &state.s, m_block); // U Vᵀ + S − M
    matmul_into(&mut ws.grad, &ws.resid, &state.v); // m×r
    ws.grad.axpy(hyper.rho * n_frac, u);
}

/// One full local iteration (Algorithm 1's loop body): inner solve, then a
/// gradient step on U with step size η, all in place. Returns the gradient
/// norm (used for convergence telemetry / Theorem 1's metric).
pub fn local_iteration(
    u: &mut Mat,
    m_block: &Mat,
    state: &mut ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
    eta: f64,
    ws: &mut Workspace,
) -> f64 {
    inner_solve(u, m_block, state, hyper, ws);
    u_gradient_into(u, m_block, state, hyper, n_frac, ws);
    let gn = ws.grad.frob_norm();
    u.axpy(-eta, &ws.grad);
    gn
}

/// Debias polish (final-output refinement, not part of Algorithm 1's
/// loop): soft thresholding biases every support entry of S by λ. Once the
/// support has stabilized, replace the soft threshold by a *hard*
/// threshold — `S = resid·1[|resid| > λ]`, i.e. keep the full residual on
/// detected spikes — and re-solve the ridge for V. With the support
/// correctly identified, `M − S` equals `L₀` on the support exactly and
/// the factorization fit becomes unbiased. Standard practice for
/// ℓ1-regularized estimators (refit on the selected support).
pub fn polish_sweep(
    u: &Mat,
    m_block: &Mat,
    state: &mut ClientState,
    hyper: &FactorHyper,
    ws: &mut Workspace,
) {
    ws.assert_shape(m_block.rows(), m_block.cols(), hyper.rank);
    // hard-threshold S on the current residual
    matmul_nt_into(&mut ws.resid, u, &state.v); // U·Vᵀ
    {
        let sd = state.s.as_mut_slice();
        let md = m_block.as_slice();
        let ud = ws.resid.as_slice();
        for i in 0..sd.len() {
            let r = md[i] - ud[i];
            sd[i] = if r.abs() > hyper.lambda { r } else { 0.0 };
        }
    }
    // exact ridge re-solve of V against the debiased S
    gram_into(&mut ws.gram, u);
    sub_into(&mut ws.resid, m_block, &state.s);
    matmul_tn_into(&mut ws.rhs, u, &ws.resid);
    ridge_solve_v_into(&mut state.v, &ws.gram, &ws.rhs, hyper.rho, &mut ws.chol, &mut ws.sol);
}

/// Curvature estimate for adaptive step sizes: the largest eigenvalue of
/// VᵀV + ρI bounds the local Lipschitz constant of ∇_U L_i in U. Estimated
/// by a few power iterations on the (r×r) Gram of V, using the
/// workspace's power-iteration buffers (no allocation).
pub fn lipschitz_estimate(state: &ClientState, hyper: &FactorHyper, ws: &mut Workspace) -> f64 {
    gram_into(&mut ws.gram, &state.v); // r×r = VᵀV
    let r = ws.gram.rows();
    ws.pow_x.fill(1.0 / (r as f64).sqrt());
    let mut lam = 0.0;
    for _ in 0..20 {
        matvec_into(&mut ws.pow_y, &ws.gram, &ws.pow_x);
        let norm = ws.pow_y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < 1e-300 {
            return hyper.rho;
        }
        lam = norm;
        for (xi, yi) in ws.pow_x.iter_mut().zip(&ws.pow_y) {
            *xi = yi / norm;
        }
    }
    lam + hyper.rho
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gram, matmul_tn, ridge_solve_v};
    use crate::rng::Pcg64;
    use crate::rpca::problem::ProblemSpec;

    fn small_problem() -> (Mat, FactorHyper) {
        let p = ProblemSpec::square(40, 3, 0.05).generate(11);
        let hyper = FactorHyper::default_for(40, 40, 3);
        (p.observed, hyper)
    }

    #[test]
    fn inner_sweep_descends_monotonically() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(1);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        let mut prev = inner_objective(&u, &m, &state, &hyper);
        for _ in 0..6 {
            inner_sweep(&u, &m, &mut state, &hyper, &mut ws);
            let cur = inner_objective(&u, &m, &state, &hyper);
            assert!(cur <= prev + 1e-9 * prev.abs().max(1.0), "{cur} > {prev}");
            prev = cur;
        }
    }

    #[test]
    fn inner_sweep_matches_allocating_composition() {
        // the workspace sweep must equal the same math written with the
        // allocating linalg twins, to the last bit of f64 rounding
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(9);
        let u = Mat::gaussian(40, 3, &mut rng);

        let mut state_ws = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_sweep(&u, &m, &mut state_ws, &hyper, &mut ws);

        let mut state_alloc = ClientState::zeros(40, 40, 3);
        let g = gram(&u);
        let resid = &m - &state_alloc.s;
        let rhs = matmul_tn(&u, &resid);
        state_alloc.v = ridge_solve_v(&g, &rhs, hyper.rho);
        let uv = crate::linalg::matmul_nt(&u, &state_alloc.v);
        residual_shrink_into(&mut state_alloc.s, &m, &uv, hyper.lambda);

        let dv = (&state_ws.v - &state_alloc.v).frob_norm();
        let ds = (&state_ws.s - &state_alloc.s).frob_norm();
        assert!(dv < 1e-12, "V deviates {dv}");
        assert!(ds < 1e-12, "S deviates {ds}");
    }

    #[test]
    fn inner_solve_reaches_fixed_point() {
        // after enough sweeps, one more sweep barely moves (V,S)
        let (m, mut hyper) = small_problem();
        hyper.inner_sweeps = 60;
        let mut rng = Pcg64::new(2);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_solve(&u, &m, &mut state, &hyper, &mut ws);
        let v_before = state.v.clone();
        let s_before = state.s.clone();
        inner_sweep(&u, &m, &mut state, &hyper, &mut ws);
        // linear convergence rate degrades as ρ → 0 (Lemma 1's strong
        // convexity is only ρ); after 60 sweeps a further sweep should
        // move the blocks by <1e-4 relative
        let dv = (&state.v - &v_before).frob_norm() / v_before.frob_norm().max(1.0);
        let ds = (&state.s - &s_before).frob_norm() / s_before.frob_norm().max(1.0);
        assert!(dv < 1e-4, "V moved {dv}");
        assert!(ds < 1e-4, "S moved {ds}");
    }

    #[test]
    fn u_gradient_matches_finite_difference() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(3);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        // fix (V,S) at some point — gradient formula holds for any (V,S)
        inner_solve(&u, &m, &mut state, &hyper, &mut ws);
        let n_frac = 1.0;
        u_gradient_into(&u, &m, &state, &hyper, n_frac, &mut ws);
        let grad = ws.grad.clone();
        let eps = 1e-6;
        let mut rng2 = Pcg64::new(4);
        for _ in 0..10 {
            let i = rng2.next_below(40) as usize;
            let j = rng2.next_below(3) as usize;
            let mut up = u.clone();
            up[(i, j)] += eps;
            let mut um = u.clone();
            um[(i, j)] -= eps;
            let fd = (local_objective(&up, &m, &state, &hyper, n_frac)
                - local_objective(&um, &m, &state, &hyper, n_frac))
                / (2.0 * eps);
            assert!(
                (fd - grad[(i, j)]).abs() < 1e-4 * grad.frob_norm().max(1.0),
                "fd {fd} vs analytic {}",
                grad[(i, j)]
            );
        }
    }

    #[test]
    fn danskin_gradient_direction_descends_g() {
        // Lemma 2: with (V,S) re-solved after the step, g(U) still
        // decreases along −∇_U L_i for small η.
        let (m, mut hyper) = small_problem();
        hyper.inner_sweeps = 15;
        let mut rng = Pcg64::new(5);
        let mut u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_solve(&u, &m, &mut state, &hyper, &mut ws);
        let g_before =
            inner_objective(&u, &m, &state, &hyper) + 0.5 * hyper.rho * u.frob_norm_sq();
        u_gradient_into(&u, &m, &state, &hyper, 1.0, &mut ws);
        let grad = ws.grad.clone();
        let lip = lipschitz_estimate(&state, &hyper, &mut ws);
        u.axpy(-0.5 / lip, &grad);
        let mut state2 = state.clone();
        inner_solve(&u, &m, &mut state2, &hyper, &mut ws);
        let g_after =
            inner_objective(&u, &m, &state2, &hyper) + 0.5 * hyper.rho * u.frob_norm_sq();
        assert!(g_after < g_before, "{g_after} !< {g_before}");
    }

    #[test]
    fn spikes_are_captured_by_s_immediately() {
        // With λ between the low-rank entry scale and the spike scale,
        // the first sweep should place (nearly) all spikes into S.
        let p = ProblemSpec::square(40, 3, 0.05).generate(12);
        let hyper = FactorHyper::default_for(40, 40, 3);
        let mut rng = Pcg64::new(6);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_sweep(&u, &m_of(&p), &mut state, &hyper, &mut ws);
        let acc = crate::rpca::metrics::support_sign_accuracy(&state.s, &p.s0);
        assert!(acc > 0.95, "support sign accuracy {acc}");
    }

    fn m_of(p: &crate::rpca::problem::RpcaProblem) -> Mat {
        p.observed.clone()
    }

    #[test]
    fn lipschitz_estimate_dominates_gram_diag() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(7);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_solve(&u, &m, &mut state, &hyper, &mut ws);
        let lip = lipschitz_estimate(&state, &hyper, &mut ws);
        let g = gram(&state.v);
        for i in 0..3 {
            assert!(lip >= g[(i, i)] - 1e-6, "lip {lip} < diag {}", g[(i, i)]);
        }
    }

    #[test]
    fn local_iteration_is_steady_state_allocation_free() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(8);
        let mut u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        // warm-up (first call settles lazy state like TLS)
        local_iteration(&mut u, &m, &mut state, &hyper, 1.0, 1e-3, &mut ws);
        let (_, allocs) = crate::alloc_counter::measure(|| {
            local_iteration(&mut u, &m, &mut state, &hyper, 1.0, 1e-3, &mut ws)
        });
        assert_eq!(allocs, 0, "local_iteration allocated {allocs} times after warm-up");
    }

    #[test]
    fn theorem2_check() {
        let h = FactorHyper::default_for(100, 100, 5);
        assert!(h.satisfies_theorem2(100, 100));
        let bad = FactorHyper { rank: 5, rho: 1e6, lambda: 1e-8, inner_sweeps: 1 };
        assert!(!bad.satisfies_theorem2(100, 100));
    }
}
