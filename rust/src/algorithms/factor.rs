//! Shared consensus-factorization machinery (paper §2.2).
//!
//! Both the centralized CF-PCA solver and every DCF-PCA client iterate the
//! same two moves on a column block `M_i`:
//!
//! 1. **Inner solve** (Eq. 7): minimize over `(V_i, S_i)` with `U` fixed.
//!    We alternate the two *exact* block updates that characterize the
//!    optimum —
//!    `V_i = (M_i − S_i)ᵀ U (UᵀU + ρI)^{-1}`  (Eq. 15, ridge solve) and
//!    `S_i = shrink_λ(M_i − U V_iᵀ)`           (Eq. 16) —
//!    `J` times. The inner objective is ρ-strongly convex (Lemma 1), each
//!    alternation is an exact coordinate minimization, so the inner
//!    objective descends monotonically (property-tested below).
//! 2. **U gradient step** (Eq. 8):
//!    `U ← U − η ∇_U L_i`,
//!    `∇_U L_i = (U V_iᵀ + S_i − M_i) V_i + ρ (n_i/n) U` (Lemma 2).
//!
//! Both moves run the **fused column-tile pipeline** (`linalg::tile`):
//! the ridge solve is column-separable, so each L2-resident panel of the
//! block computes its RHS, V rows, and shrunk S columns in one DRAM pass
//! over M per sweep (the gradient takes one more), instead of the 4–6
//! full-matrix streams of the multi-pass formulation. Panels fan out
//! across a [`ThreadPool`] in fixed *slots* — a shape-derived
//! decomposition with slot-ordered gradient reduction — so results are
//! bitwise identical at any thread count. The multi-pass path survives
//! as the parity [`oracle`] used by tests and the hot-path bench.
//!
//! The block itself arrives through a [`DataSource`], not `&Mat`: a
//! resident matrix hands out zero-copy panel views (and `&Mat` coerces
//! to `&dyn DataSource`, so the in-memory call surface is unchanged),
//! while a `ShardSource` streams each panel from disk into its slot's
//! `Workspace::io` buffer with readahead of the slot's next panel — the
//! same sweep runs out-of-core, bit-identically. Fetch failures (an
//! out-of-core read can fail; a resident one cannot) surface as `Err`
//! from the sweep, which is why these functions return [`Result`].
//!
//! Every function here borrows a [`Workspace`] sized for the block
//! (`(m, n_i, p)`, panel width from the source) instead of allocating
//! temporaries: the inner sweep and the gradient run J × K × T times per
//! DCF-PCA run, and on that path steady-state heap traffic is zero —
//! resident *and* streamed (asserted by counting-allocator tests in
//! `coordinator::kernel` and `data::source`).
//!
//! This module is the native (f64) twin of the AOT-compiled JAX/Pallas
//! `client_update` artifact; `runtime::executor` checks the two against
//! each other.

use crate::data::DataSource;
use crate::error::{Error, Result};
use crate::linalg::{
    cholesky_shifted_into, gram_into, matmul_nt, matvec_into, tile, GradCtx, Mat, PanelCtx,
    PanelScratch, PanelView, Workspace,
};
use crate::runtime::pool::{Slots, ThreadPool};

/// Hyperparameters of the factorized objective (paper Eq. 4).
#[derive(Clone, Copy, Debug)]
pub struct FactorHyper {
    /// factorization width p (≥ true rank r; = r for exact-rank runs)
    pub rank: usize,
    /// ridge weight ρ on ‖U‖²_F and ‖V‖²_F
    pub rho: f64,
    /// ℓ1 weight λ on S
    pub lambda: f64,
    /// inner alternation sweeps J per local iteration
    pub inner_sweeps: usize,
}

impl FactorHyper {
    /// Defaults that recover the paper's §4 synthetic instances:
    /// λ at the low-rank entry scale (≈√r — entries of L₀ are N(0, r)) and
    /// far below the spike scale √(mn); ρ small. The soft-threshold bias
    /// on the support is λ per entry, giving an error floor of
    /// `s·mn·λ² / (‖L₀‖² + ‖S₀‖²)` — with λ = √r that is ~1e-4 relative,
    /// matching the floors visible in the paper's Fig. 1; the final
    /// [`polish_sweep`] debias removes it. Satisfies Theorem 2
    /// (ρ² ≤ λ²·mn).
    pub fn default_for(m: usize, n: usize, rank: usize) -> Self {
        let lambda = (rank as f64).sqrt().max(1.0);
        let rho = 1e-2;
        debug_assert!(rho * rho <= lambda * lambda * (m * n) as f64);
        FactorHyper { rank, rho, lambda, inner_sweeps: 3 }
    }

    /// Theorem 2's necessary condition for exact recovery: ρ² ≤ λ²·m·n.
    pub fn satisfies_theorem2(&self, m: usize, n: usize) -> bool {
        self.rho * self.rho <= self.lambda * self.lambda * (m as f64) * (n as f64)
    }
}

/// Mutable per-client state: the right factor and sparse component for one
/// column block. `V` is n_i×p, `S` is m×n_i. Persisted across rounds
/// (warm start, per Algorithm 1: "set V_i^(0), S_i^(0) … from the last epoch").
#[derive(Clone, Debug)]
pub struct ClientState {
    pub v: Mat,
    pub s: Mat,
}

impl ClientState {
    /// Cold start: V = 0, S = 0. (The paper randomizes V, but the first
    /// inner sweep solves V exactly given S, which makes the init
    /// irrelevant for J ≥ 1; zeros keep the artifact path deterministic.)
    pub fn zeros(m: usize, n_i: usize, rank: usize) -> Self {
        ClientState { v: Mat::zeros(n_i, rank), s: Mat::zeros(m, n_i) }
    }
}

/// Fan `panels` of `data` across the pool as [`tile::NUM_SLOTS`]-capped
/// slots: slot `s` processes panels `s, s + jobs, s + 2·jobs, …` in
/// order with its private scratch and I/O lane. `jobs` depends on shape
/// only, so the work (and any slot-ordered reduction over the `jobs`
/// scratches) is deterministic at every thread count. Each panel is
/// fetched from the source (zero-copy for resident blocks, a positioned
/// read + next-panel readahead for shards) and handed to the closure as
/// `(panel, first, view, scratch)` — `first` is true for the slot's
/// first panel, so per-slot accumulators can be reset without a second
/// copy of the stride formula. A fetch failure stops that slot and is
/// re-raised after the dispatch drains (first slot in order wins).
/// Returns `jobs`. No allocation on the success path.
fn dispatch_panels(
    pool: &ThreadPool,
    data: &dyn DataSource,
    panels: usize,
    slots: &mut [PanelScratch],
    io: &mut [Vec<f64>],
    run: impl Fn(usize, bool, PanelView<'_>, &mut PanelScratch) + Sync,
) -> Result<usize> {
    let jobs = tile::NUM_SLOTS.min(panels).max(1);
    let access = Slots::new(&mut slots[..jobs]);
    let io_access = Slots::new(&mut io[..jobs]);
    let mut errs: [Option<Error>; tile::NUM_SLOTS] = std::array::from_fn(|_| None);
    let err_access = Slots::new(&mut errs[..jobs]);
    pool.run(jobs, &|s| {
        // SAFETY: each job index is claimed exactly once per dispatch.
        let scratch = unsafe { access.get(s) };
        let buf = unsafe { io_access.get(s) };
        let mut k = s;
        let mut first = true;
        while k < panels {
            let next = k + jobs;
            let prefetch = if next < panels { Some(next) } else { None };
            match data.panel(k, prefetch, buf) {
                Ok(view) => run(k, first, view, scratch),
                Err(e) => {
                    // SAFETY: slot-private lane, claimed once.
                    unsafe { *err_access.get(s) = Some(e) };
                    break;
                }
            }
            first = false;
            k = next;
        }
    });
    for e in errs.iter_mut() {
        if let Some(e) = e.take() {
            return Err(e);
        }
    }
    Ok(jobs)
}

/// One exact alternation sweep of the inner problem (Eqs. 15 + 16) as a
/// fused panel pipeline — one pass over `data`'s panels (DRAM for
/// resident blocks, disk-streamed for shards), entirely inside `ws`,
/// panels fanned across `pool`. No allocation.
pub fn inner_sweep(
    u: &Mat,
    data: &dyn DataSource,
    state: &mut ClientState,
    hyper: &FactorHyper,
    pool: &ThreadPool,
    ws: &mut Workspace,
) -> Result<()> {
    factor_ridge(u, data, hyper, ws);
    let (m, n_i, w) = (data.rows(), data.cols(), data.panel_width());
    let ctx = PanelCtx::new(u, &ws.chol, m, n_i, w, &mut state.v, &mut state.s, hyper.lambda);
    let panels = ctx.panels();
    dispatch_panels(
        pool,
        data,
        panels,
        &mut ws.slots,
        &mut ws.io,
        |k, _, mp: PanelView<'_>, scratch| ctx.sweep_panel(k, mp, scratch),
    )?;
    Ok(())
}

/// Shared sweep/polish preamble: check the workspace against the
/// source's shape *and* panel width (a workspace sized for one
/// decomposition must never run another) and factor (UᵀU + ρI) into
/// `ws.chol` — every column's ridge system shares it.
fn factor_ridge(u: &Mat, data: &dyn DataSource, hyper: &FactorHyper, ws: &mut Workspace) {
    assert_ws_fits_source(data, hyper, ws);
    gram_into(&mut ws.gram, u);
    assert!(
        cholesky_shifted_into(&mut ws.chol, &ws.gram, hyper.rho),
        "G+ρI must be SPD for ρ>0"
    );
}

/// The workspace must match the source's shape *and* panel width — the
/// scratch lanes are sized for one decomposition, and running another
/// would index past them. Guarded at the top of every panel-dispatching
/// entry point (sweep, polish, gradient) so the failure is this message,
/// not an opaque slice panic inside a panel kernel.
fn assert_ws_fits_source(data: &dyn DataSource, hyper: &FactorHyper, ws: &Workspace) {
    ws.assert_shape(data.rows(), data.cols(), hyper.rank);
    assert_eq!(
        ws.panel_width(),
        data.panel_width(),
        "workspace panel width does not match the data source's \
         (size the workspace with Workspace::for_source)"
    );
}

/// Solve the inner problem (Eq. 7) to tolerance by J alternation sweeps.
pub fn inner_solve(
    u: &Mat,
    data: &dyn DataSource,
    state: &mut ClientState,
    hyper: &FactorHyper,
    pool: &ThreadPool,
    ws: &mut Workspace,
) -> Result<()> {
    for _ in 0..hyper.inner_sweeps {
        inner_sweep(u, data, state, hyper, pool, ws)?;
    }
    Ok(())
}

/// Inner objective value (Eq. 7's argument):
/// `1/2‖U Vᵀ + S − M‖²_F + ρ/2‖V‖²_F + λ‖S‖₁`.
/// Telemetry-only (tests, per-iteration logging) — allocates.
pub fn inner_objective(u: &Mat, m_block: &Mat, state: &ClientState, hyper: &FactorHyper) -> f64 {
    let uv = matmul_nt(u, &state.v);
    let fit = &(&uv + &state.s) - m_block;
    0.5 * fit.frob_norm_sq()
        + 0.5 * hyper.rho * state.v.frob_norm_sq()
        + hyper.lambda * crate::linalg::l1_norm(&state.s)
}

/// Local objective L_i (Eq. 11) = inner objective + ρ/2·(n_i/n)‖U‖²_F.
pub fn local_objective(
    u: &Mat,
    m_block: &Mat,
    state: &ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
) -> f64 {
    inner_objective(u, m_block, state, hyper) + 0.5 * hyper.rho * n_frac * u.frob_norm_sq()
}

/// ∇_U L_i (Lemma 2): `(U Vᵀ + S − M) V + ρ (n_i/n) U`, written into
/// `ws.grad`. One fused DRAM pass over the block: each slot accumulates
/// its panels' contributions into private scratch, reduced here in slot
/// order (deterministic at any thread count). `n_frac` is n_i/n (1.0 for
/// the centralized solver). No allocation.
pub fn u_gradient_into(
    u: &Mat,
    data: &dyn DataSource,
    state: &ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
    pool: &ThreadPool,
    ws: &mut Workspace,
) -> Result<()> {
    assert_ws_fits_source(data, hyper, ws);
    let (m, n_i, w) = (data.rows(), data.cols(), data.panel_width());
    let ctx = GradCtx::new(u, m, n_i, w, &state.v, &state.s);
    let panels = ctx.panels();
    let jobs = dispatch_panels(
        pool,
        data,
        panels,
        &mut ws.slots,
        &mut ws.io,
        |k, first, mp: PanelView<'_>, scratch| {
            if first {
                // first panel of this slot: start the accumulator fresh
                scratch.grad_acc.fill(0.0);
            }
            ctx.grad_panel(k, mp, scratch);
        },
    )?;
    // fixed-order reduction: Σ_slots acc + ρ·(n_i/n)·U
    ws.grad.copy_from(&ws.slots[0].grad_acc);
    for s in 1..jobs {
        ws.grad.axpy(1.0, &ws.slots[s].grad_acc);
    }
    ws.grad.axpy(hyper.rho * n_frac, u);
    Ok(())
}

/// One full local iteration (Algorithm 1's loop body): inner solve, then a
/// gradient step on U with step size η, all in place. Returns the gradient
/// norm (used for convergence telemetry / Theorem 1's metric).
#[allow(clippy::too_many_arguments)]
pub fn local_iteration(
    u: &mut Mat,
    data: &dyn DataSource,
    state: &mut ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
    eta: f64,
    pool: &ThreadPool,
    ws: &mut Workspace,
) -> Result<f64> {
    inner_solve(u, data, state, hyper, pool, ws)?;
    u_gradient_into(u, data, state, hyper, n_frac, pool, ws)?;
    let gn = ws.grad.frob_norm();
    u.axpy(-eta, &ws.grad);
    Ok(gn)
}

/// Debias polish (final-output refinement, not part of Algorithm 1's
/// loop): soft thresholding biases every support entry of S by λ. Once the
/// support has stabilized, replace the soft threshold by a *hard*
/// threshold — `S = resid·1[|resid| > λ]`, i.e. keep the full residual on
/// detected spikes — and re-solve the ridge for V. With the support
/// correctly identified, `M − S` equals `L₀` on the support exactly and
/// the factorization fit becomes unbiased. Standard practice for
/// ℓ1-regularized estimators (refit on the selected support). Runs the
/// same fused panel pipeline as [`inner_sweep`].
pub fn polish_sweep(
    u: &Mat,
    data: &dyn DataSource,
    state: &mut ClientState,
    hyper: &FactorHyper,
    pool: &ThreadPool,
    ws: &mut Workspace,
) -> Result<()> {
    factor_ridge(u, data, hyper, ws);
    let (m, n_i, w) = (data.rows(), data.cols(), data.panel_width());
    let ctx = PanelCtx::new(u, &ws.chol, m, n_i, w, &mut state.v, &mut state.s, hyper.lambda);
    let panels = ctx.panels();
    dispatch_panels(
        pool,
        data,
        panels,
        &mut ws.slots,
        &mut ws.io,
        |k, _, mp: PanelView<'_>, scratch| ctx.polish_panel(k, mp, scratch),
    )?;
    Ok(())
}

/// Curvature estimate for adaptive step sizes: the largest eigenvalue of
/// VᵀV + ρI bounds the local Lipschitz constant of ∇_U L_i in U. Estimated
/// by a few power iterations on the (r×r) Gram of V, using the
/// workspace's power-iteration buffers (no allocation).
pub fn lipschitz_estimate(state: &ClientState, hyper: &FactorHyper, ws: &mut Workspace) -> f64 {
    gram_into(&mut ws.gram, &state.v); // r×r = VᵀV
    let r = ws.gram.rows();
    ws.pow_x.fill(1.0 / (r as f64).sqrt());
    let mut lam = 0.0;
    for _ in 0..20 {
        matvec_into(&mut ws.pow_y, &ws.gram, &ws.pow_x);
        let norm = ws.pow_y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < 1e-300 {
            return hyper.rho;
        }
        lam = norm;
        for (xi, yi) in ws.pow_x.iter_mut().zip(&ws.pow_y) {
            *xi = yi / norm;
        }
    }
    lam + hyper.rho
}

/// The PR-1 multi-pass formulation, preserved verbatim as the parity
/// oracle: every stage is a separate full-matrix kernel (4–6 DRAM
/// streams of the block per sweep). Tests pin the fused tile pipeline
/// to this path at 1e-12; `benches/kernel_hotpath.rs` uses it as the
/// before-side of the fusion speedup. Not for production use.
pub mod oracle {
    use super::{ClientState, FactorHyper};
    use crate::linalg::{
        gram_into, matmul_into, matmul_nt_into, matmul_tn_into, matvec_into, residual_into,
        residual_shrink_into, ridge_solve_v_into, sub_into, Mat,
    };

    /// The old Workspace layout: full-width intermediates for each
    /// separate pass (`resid` is a whole m×n_i stream).
    #[derive(Clone, Debug)]
    pub struct MultipassWorkspace {
        pub gram: Mat,
        pub chol: Mat,
        /// p×n_i — right-hand side Uᵀ(M−S)
        pub rhs: Mat,
        /// p×n_i — ridge-solve intermediate Vᵀ
        pub sol: Mat,
        /// m×n_i — block-sized residual (M−S, then U·Vᵀ, then U·Vᵀ+S−M)
        pub resid: Mat,
        pub grad: Mat,
        pub pow_x: Vec<f64>,
        pub pow_y: Vec<f64>,
    }

    impl MultipassWorkspace {
        pub fn new(m: usize, n_i: usize, p: usize) -> Self {
            MultipassWorkspace {
                gram: Mat::zeros(p, p),
                chol: Mat::zeros(p, p),
                rhs: Mat::zeros(p, n_i),
                sol: Mat::zeros(p, n_i),
                resid: Mat::zeros(m, n_i),
                grad: Mat::zeros(m, p),
                pow_x: vec![0.0; p],
                pow_y: vec![0.0; p],
            }
        }
    }

    /// Multi-pass Eqs. 15 + 16 (the PR-1 `inner_sweep`).
    pub fn inner_sweep(
        u: &Mat,
        m_block: &Mat,
        state: &mut ClientState,
        hyper: &FactorHyper,
        ws: &mut MultipassWorkspace,
    ) {
        // V ← (M − S)ᵀ U (UᵀU + ρI)^{-1}
        gram_into(&mut ws.gram, u);
        sub_into(&mut ws.resid, m_block, &state.s); // M − S
        matmul_tn_into(&mut ws.rhs, u, &ws.resid); // r×n_i
        ridge_solve_v_into(&mut state.v, &ws.gram, &ws.rhs, hyper.rho, &mut ws.chol, &mut ws.sol);
        // S ← shrink_λ(M − U Vᵀ)
        matmul_nt_into(&mut ws.resid, u, &state.v); // U·Vᵀ
        residual_shrink_into(&mut state.s, m_block, &ws.resid, hyper.lambda);
    }

    pub fn inner_solve(
        u: &Mat,
        m_block: &Mat,
        state: &mut ClientState,
        hyper: &FactorHyper,
        ws: &mut MultipassWorkspace,
    ) {
        for _ in 0..hyper.inner_sweeps {
            inner_sweep(u, m_block, state, hyper, ws);
        }
    }

    /// Multi-pass Lemma 2 gradient (the PR-1 `u_gradient_into`).
    pub fn u_gradient_into(
        u: &Mat,
        m_block: &Mat,
        state: &ClientState,
        hyper: &FactorHyper,
        n_frac: f64,
        ws: &mut MultipassWorkspace,
    ) {
        residual_into(&mut ws.resid, u, &state.v, &state.s, m_block); // U Vᵀ + S − M
        matmul_into(&mut ws.grad, &ws.resid, &state.v); // m×r
        ws.grad.axpy(hyper.rho * n_frac, u);
    }

    /// Multi-pass debias polish (the PR-1 `polish_sweep`).
    pub fn polish_sweep(
        u: &Mat,
        m_block: &Mat,
        state: &mut ClientState,
        hyper: &FactorHyper,
        ws: &mut MultipassWorkspace,
    ) {
        matmul_nt_into(&mut ws.resid, u, &state.v); // U·Vᵀ
        {
            let sd = state.s.as_mut_slice();
            let md = m_block.as_slice();
            let ud = ws.resid.as_slice();
            for i in 0..sd.len() {
                let r = md[i] - ud[i];
                sd[i] = if r.abs() > hyper.lambda { r } else { 0.0 };
            }
        }
        gram_into(&mut ws.gram, u);
        sub_into(&mut ws.resid, m_block, &state.s);
        matmul_tn_into(&mut ws.rhs, u, &ws.resid);
        ridge_solve_v_into(&mut state.v, &ws.gram, &ws.rhs, hyper.rho, &mut ws.chol, &mut ws.sol);
    }

    pub fn lipschitz_estimate(
        state: &ClientState,
        hyper: &FactorHyper,
        ws: &mut MultipassWorkspace,
    ) -> f64 {
        gram_into(&mut ws.gram, &state.v);
        let r = ws.gram.rows();
        ws.pow_x.fill(1.0 / (r as f64).sqrt());
        let mut lam = 0.0;
        for _ in 0..20 {
            matvec_into(&mut ws.pow_y, &ws.gram, &ws.pow_x);
            let norm = ws.pow_y.iter().map(|v| v * v).sum::<f64>().sqrt();
            if norm < 1e-300 {
                return hyper.rho;
            }
            lam = norm;
            for (xi, yi) in ws.pow_x.iter_mut().zip(&ws.pow_y) {
                *xi = yi / norm;
            }
        }
        lam + hyper.rho
    }

    #[allow(clippy::too_many_arguments)]
    pub fn local_iteration(
        u: &mut Mat,
        m_block: &Mat,
        state: &mut ClientState,
        hyper: &FactorHyper,
        n_frac: f64,
        eta: f64,
        ws: &mut MultipassWorkspace,
    ) -> f64 {
        inner_solve(u, m_block, state, hyper, ws);
        u_gradient_into(u, m_block, state, hyper, n_frac, ws);
        let gn = ws.grad.frob_norm();
        u.axpy(-eta, &ws.grad);
        gn
    }

    /// The PR-1 local epoch (K multi-pass iterations + curvature) —
    /// the bench baseline the fused pipeline is measured against.
    #[allow(clippy::too_many_arguments)]
    pub fn local_epoch(
        u: &mut Mat,
        m_block: &Mat,
        state: &mut ClientState,
        hyper: &FactorHyper,
        n_frac: f64,
        eta: f64,
        k_local: usize,
        ws: &mut MultipassWorkspace,
    ) -> (f64, f64) {
        let mut grad_norm = 0.0;
        for _ in 0..k_local {
            grad_norm = local_iteration(u, m_block, state, hyper, n_frac, eta, ws);
        }
        let lipschitz = lipschitz_estimate(state, hyper, ws);
        (grad_norm, lipschitz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::{gram, matmul_tn, residual_shrink_into, ridge_solve_v};
    use crate::rng::Pcg64;
    use crate::rpca::problem::ProblemSpec;
    use crate::runtime::pool;

    fn small_problem() -> (Mat, FactorHyper) {
        let p = ProblemSpec::square(40, 3, 0.05).generate(11);
        let hyper = FactorHyper::default_for(40, 40, 3);
        (p.observed, hyper)
    }

    fn test_pool() -> &'static crate::runtime::pool::ThreadPool {
        pool::global()
    }

    #[test]
    fn inner_sweep_descends_monotonically() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(1);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        let mut prev = inner_objective(&u, &m, &state, &hyper);
        for _ in 0..6 {
            inner_sweep(&u, &m, &mut state, &hyper, test_pool(), &mut ws).unwrap();
            let cur = inner_objective(&u, &m, &state, &hyper);
            assert!(cur <= prev + 1e-9 * prev.abs().max(1.0), "{cur} > {prev}");
            prev = cur;
        }
    }

    #[test]
    fn inner_sweep_matches_allocating_composition() {
        // the fused panel sweep must equal the same math written with the
        // allocating linalg twins, to fp-reordering tolerance
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(9);
        let u = Mat::gaussian(40, 3, &mut rng);

        let mut state_ws = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_sweep(&u, &m, &mut state_ws, &hyper, test_pool(), &mut ws).unwrap();

        let mut state_alloc = ClientState::zeros(40, 40, 3);
        let g = gram(&u);
        let resid = &m - &state_alloc.s;
        let rhs = matmul_tn(&u, &resid);
        state_alloc.v = ridge_solve_v(&g, &rhs, hyper.rho);
        let uv = crate::linalg::matmul_nt(&u, &state_alloc.v);
        residual_shrink_into(&mut state_alloc.s, &m, &uv, hyper.lambda);

        let dv = (&state_ws.v - &state_alloc.v).frob_norm();
        let ds = (&state_ws.s - &state_alloc.s).frob_norm();
        assert!(dv < 1e-12, "V deviates {dv}");
        assert!(ds < 1e-12, "S deviates {ds}");
    }

    #[test]
    fn fused_sweep_and_gradient_match_multipass_oracle() {
        // the tentpole parity pin: fused panels vs the preserved PR-1
        // multi-pass path, across several shapes including panel edges
        // shapes chosen to cover one-panel blocks, multi-panel blocks
        // (panel_width(256,·)=64, panel_width(512,·)=32), and a ragged
        // last panel
        for &(mdim, ndim, p) in &[
            (40usize, 40usize, 3usize),
            (33, 57, 4),
            (24, 7, 2),
            (256, 300, 5),
            (512, 100, 4),
        ] {
            let prob = ProblemSpec { m: mdim, n: ndim, rank: p, sparsity: 0.05 }.generate(77);
            let hyper = FactorHyper::default_for(mdim, ndim, p);
            let mut rng = Pcg64::new(13);
            let u = Mat::gaussian(mdim, p, &mut rng);

            let mut st_fused = ClientState::zeros(mdim, ndim, p);
            let mut ws = Workspace::new(mdim, ndim, p);
            let mut st_oracle = st_fused.clone();
            let mut ows = oracle::MultipassWorkspace::new(mdim, ndim, p);

            for _ in 0..3 {
                inner_sweep(&u, &prob.observed, &mut st_fused, &hyper, test_pool(), &mut ws).unwrap();
                oracle::inner_sweep(&u, &prob.observed, &mut st_oracle, &hyper, &mut ows);
            }
            let dv = (&st_fused.v - &st_oracle.v).frob_norm() / st_oracle.v.frob_norm().max(1.0);
            let ds = (&st_fused.s - &st_oracle.s).frob_norm() / st_oracle.s.frob_norm().max(1.0);
            assert!(dv < 1e-12, "V deviates {dv} at {mdim}x{ndim} p={p}");
            assert!(ds < 1e-12, "S deviates {ds} at {mdim}x{ndim} p={p}");

            u_gradient_into(&u, &prob.observed, &st_fused, &hyper, 0.7, test_pool(), &mut ws).unwrap();
            oracle::u_gradient_into(&u, &prob.observed, &st_oracle, &hyper, 0.7, &mut ows);
            let dg = (&ws.grad - &ows.grad).frob_norm() / ows.grad.frob_norm().max(1.0);
            assert!(dg < 1e-12, "grad deviates {dg} at {mdim}x{ndim} p={p}");

            polish_sweep(&u, &prob.observed, &mut st_fused, &hyper, test_pool(), &mut ws).unwrap();
            oracle::polish_sweep(&u, &prob.observed, &mut st_oracle, &hyper, &mut ows);
            let dv = (&st_fused.v - &st_oracle.v).frob_norm() / st_oracle.v.frob_norm().max(1.0);
            let ds = (&st_fused.s - &st_oracle.s).frob_norm() / st_oracle.s.frob_norm().max(1.0);
            assert!(dv < 1e-12, "polish V deviates {dv} at {mdim}x{ndim} p={p}");
            assert!(ds < 1e-12, "polish S deviates {ds} at {mdim}x{ndim} p={p}");
        }
    }

    #[test]
    fn inner_solve_reaches_fixed_point() {
        // after enough sweeps, one more sweep barely moves (V,S)
        let (m, mut hyper) = small_problem();
        hyper.inner_sweeps = 60;
        let mut rng = Pcg64::new(2);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_solve(&u, &m, &mut state, &hyper, test_pool(), &mut ws).unwrap();
        let v_before = state.v.clone();
        let s_before = state.s.clone();
        inner_sweep(&u, &m, &mut state, &hyper, test_pool(), &mut ws).unwrap();
        // linear convergence rate degrades as ρ → 0 (Lemma 1's strong
        // convexity is only ρ); after 60 sweeps a further sweep should
        // move the blocks by <1e-4 relative
        let dv = (&state.v - &v_before).frob_norm() / v_before.frob_norm().max(1.0);
        let ds = (&state.s - &s_before).frob_norm() / s_before.frob_norm().max(1.0);
        assert!(dv < 1e-4, "V moved {dv}");
        assert!(ds < 1e-4, "S moved {ds}");
    }

    #[test]
    fn u_gradient_matches_finite_difference() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(3);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        // fix (V,S) at some point — gradient formula holds for any (V,S)
        inner_solve(&u, &m, &mut state, &hyper, test_pool(), &mut ws).unwrap();
        let n_frac = 1.0;
        u_gradient_into(&u, &m, &state, &hyper, n_frac, test_pool(), &mut ws).unwrap();
        let grad = ws.grad.clone();
        let eps = 1e-6;
        let mut rng2 = Pcg64::new(4);
        for _ in 0..10 {
            let i = rng2.next_below(40) as usize;
            let j = rng2.next_below(3) as usize;
            let mut up = u.clone();
            up[(i, j)] += eps;
            let mut um = u.clone();
            um[(i, j)] -= eps;
            let fd = (local_objective(&up, &m, &state, &hyper, n_frac)
                - local_objective(&um, &m, &state, &hyper, n_frac))
                / (2.0 * eps);
            assert!(
                (fd - grad[(i, j)]).abs() < 1e-4 * grad.frob_norm().max(1.0),
                "fd {fd} vs analytic {}",
                grad[(i, j)]
            );
        }
    }

    #[test]
    fn danskin_gradient_direction_descends_g() {
        // Lemma 2: with (V,S) re-solved after the step, g(U) still
        // decreases along −∇_U L_i for small η.
        let (m, mut hyper) = small_problem();
        hyper.inner_sweeps = 15;
        let mut rng = Pcg64::new(5);
        let mut u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_solve(&u, &m, &mut state, &hyper, test_pool(), &mut ws).unwrap();
        let g_before =
            inner_objective(&u, &m, &state, &hyper) + 0.5 * hyper.rho * u.frob_norm_sq();
        u_gradient_into(&u, &m, &state, &hyper, 1.0, test_pool(), &mut ws).unwrap();
        let grad = ws.grad.clone();
        let lip = lipschitz_estimate(&state, &hyper, &mut ws);
        u.axpy(-0.5 / lip, &grad);
        let mut state2 = state.clone();
        inner_solve(&u, &m, &mut state2, &hyper, test_pool(), &mut ws).unwrap();
        let g_after =
            inner_objective(&u, &m, &state2, &hyper) + 0.5 * hyper.rho * u.frob_norm_sq();
        assert!(g_after < g_before, "{g_after} !< {g_before}");
    }

    #[test]
    fn spikes_are_captured_by_s_immediately() {
        // With λ between the low-rank entry scale and the spike scale,
        // the first sweep should place (nearly) all spikes into S.
        let p = ProblemSpec::square(40, 3, 0.05).generate(12);
        let hyper = FactorHyper::default_for(40, 40, 3);
        let mut rng = Pcg64::new(6);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_sweep(&u, &m_of(&p), &mut state, &hyper, test_pool(), &mut ws).unwrap();
        let acc = crate::rpca::metrics::support_sign_accuracy(&state.s, &p.s0);
        assert!(acc > 0.95, "support sign accuracy {acc}");
    }

    fn m_of(p: &crate::rpca::problem::RpcaProblem) -> Mat {
        p.observed.clone()
    }

    #[test]
    fn lipschitz_estimate_dominates_gram_diag() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(7);
        let u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        inner_solve(&u, &m, &mut state, &hyper, test_pool(), &mut ws).unwrap();
        let lip = lipschitz_estimate(&state, &hyper, &mut ws);
        let g = gram(&state.v);
        for i in 0..3 {
            assert!(lip >= g[(i, i)] - 1e-6, "lip {lip} < diag {}", g[(i, i)]);
        }
    }

    #[test]
    fn local_iteration_is_steady_state_allocation_free() {
        let (m, hyper) = small_problem();
        let mut rng = Pcg64::new(8);
        let mut u = Mat::gaussian(40, 3, &mut rng);
        let mut state = ClientState::zeros(40, 40, 3);
        let mut ws = Workspace::new(40, 40, 3);
        let pool = test_pool();
        // warm-up (first call settles lazy state like TLS)
        local_iteration(&mut u, &m, &mut state, &hyper, 1.0, 1e-3, pool, &mut ws).unwrap();
        let (_, allocs) = crate::alloc_counter::measure(|| {
            local_iteration(&mut u, &m, &mut state, &hyper, 1.0, 1e-3, pool, &mut ws)
        });
        assert_eq!(allocs, 0, "local_iteration allocated {allocs} times after warm-up");
    }

    #[test]
    fn theorem2_check() {
        let h = FactorHyper::default_for(100, 100, 5);
        assert!(h.satisfies_theorem2(100, 100));
        let bad = FactorHyper { rank: 5, rho: 1e6, lambda: 1e-8, inner_sweeps: 1 };
        assert!(!bad.satisfies_theorem2(100, 100));
    }
}
