//! APGM baseline — accelerated proximal gradient for the relaxed RPCA
//! objective (paper Eq. 3), following Lin et al. 2009 [paper ref 9]:
//!
//!   min_{L,S} μ‖L‖_* + μλ‖S‖₁ + 1/2‖L + S − M‖²_F
//!
//! with Nesterov acceleration and continuation on μ. Each iteration costs
//! one SVT (the prox of the nuclear norm) — the SVD the paper points to as
//! the reason convex methods cannot be distributed. SVTs use the exact
//! Jacobi SVD below `SVD_EXACT_LIMIT`, randomized truncated SVD (with an
//! adaptively grown sketch rank) above it.

use std::time::Instant;

use crate::linalg::{rsvd_svt, svt, Mat};
use crate::rpca::problem::RpcaProblem;
use crate::runtime::pool::BandSlice;

use super::traits::{IterRecord, RpcaSolver, SolveResult, StopCriteria};

/// Below this min(m,n), use the exact Jacobi SVD for SVT steps.
const SVD_EXACT_LIMIT: usize = 160;

/// Accelerated-proximal-gradient RPCA solver.
#[derive(Clone, Debug)]
pub struct Apgm {
    /// ℓ1 weight relative to the nuclear norm; default 1/√max(m,n)
    pub lambda: Option<f64>,
    /// continuation decay μ_{k+1} = max(κ·μ_k, μ̄)
    pub mu_decay: f64,
    /// floor ratio μ̄ = μ₀ · mu_floor
    pub mu_floor: f64,
    pub stop: StopCriteria,
    /// initial sketch rank for randomized SVTs
    pub svt_rank_hint: usize,
}

impl Apgm {
    pub fn new() -> Self {
        Apgm {
            lambda: None,
            mu_decay: 0.9,
            mu_floor: 1e-9,
            stop: StopCriteria { max_iters: 200, tol: 1e-7 },
            svt_rank_hint: 16,
        }
    }

    pub fn with_stop(mut self, stop: StopCriteria) -> Self {
        self.stop = stop;
        self
    }
}

impl Default for Apgm {
    fn default() -> Self {
        Self::new()
    }
}

/// Top singular value via power iteration on AᵀA (cheap, used for μ₀).
pub fn spectral_norm(a: &Mat, iters: usize) -> f64 {
    let (_, n) = a.shape();
    let mut rng = crate::rng::Pcg64::new(0x5150);
    let mut x = Mat::gaussian(n, 1, &mut rng);
    let mut sigma = 0.0;
    for _ in 0..iters {
        let y = crate::linalg::matmul(a, &x); // m×1
        let z = crate::linalg::matmul_tn(a, &y); // n×1
        let norm = z.frob_norm();
        if norm < 1e-300 {
            return 0.0;
        }
        sigma = (norm / x.frob_norm().max(1e-300)).sqrt();
        x = z.scale(1.0 / norm);
    }
    sigma
}

/// SVT dispatcher: exact for small problems, randomized above the limit.
/// Returns (thresholded, retained rank, next rank hint).
fn svt_step(a: &Mat, tau: f64, rank_hint: usize, seed: u64) -> (Mat, usize, usize) {
    let min_dim = a.rows().min(a.cols());
    if min_dim <= SVD_EXACT_LIMIT {
        let (out, rank) = svt(a, tau);
        (out, rank, rank_hint)
    } else {
        let mut hint = rank_hint.min(min_dim);
        loop {
            let (out, rank) = rsvd_svt(a, tau, hint, seed);
            // if the sketch saturated, the true post-SVT rank may exceed it:
            // grow and retry (standard predict-rank trick from the IALM code)
            if rank < hint || hint == min_dim {
                let next = if rank + 5 >= hint { (hint * 2).min(min_dim) } else { hint };
                return (out, rank, next.max(rank + 5).min(min_dim));
            }
            hint = (hint * 2).min(min_dim);
        }
    }
}

impl RpcaSolver for Apgm {
    fn name(&self) -> &'static str {
        "APGM"
    }

    fn solve(&self, observed: &Mat, truth: Option<&RpcaProblem>) -> SolveResult {
        let (m, n) = observed.shape();
        let start = Instant::now();
        let lambda = self.lambda.unwrap_or(1.0 / (m.max(n) as f64).sqrt());
        let norm2 = spectral_norm(observed, 30);
        let mut mu = 0.99 * norm2;
        let mu_bar = self.mu_floor * norm2.max(1e-300);

        let mut l = Mat::zeros(m, n);
        let mut s = Mat::zeros(m, n);
        let mut l_prev = Mat::zeros(m, n);
        let mut s_prev = Mat::zeros(m, n);
        // reused prox-input buffers: the extrapolation points, smooth-part
        // residual, and both gradient steps are fused into two passes that
        // write these fixed buffers instead of allocating five m×n
        // temporaries per iteration
        let mut gl = Mat::zeros(m, n);
        let mut gs = Mat::zeros(m, n);
        let mut t_k: f64 = 1.0;
        let mut t_prev: f64 = 1.0;
        let mut rank_hint = self.svt_rank_hint;

        let mut history = Vec::new();
        let mut converged = false;
        let mut iters = 0;
        let m_norm = observed.frob_norm().max(1e-300);
        // fused elementwise passes fan across the process-wide pool in
        // fixed bands (deterministic at any `--threads`)
        let pool = crate::runtime::pool::global();

        for k in 0..self.stop.max_iters {
            // extrapolation points Y_L = L + β(L − L_prev), Y_S likewise;
            // gradient of the smooth part 1/2‖Y_L + Y_S − M‖² at (Y_L, Y_S):
            // G_L = Y_L − resid/2, G_S = Y_S − resid/2 — all in one pass
            let beta = (t_prev - 1.0) / t_k;
            {
                let glv = BandSlice::new(gl.as_mut_slice());
                let gsv = BandSlice::new(gs.as_mut_slice());
                let ld = l.as_slice();
                let lpd = l_prev.as_slice();
                let sd = s.as_slice();
                let spd = s_prev.as_slice();
                let md = observed.as_slice();
                pool.run_bands(md.len(), &|_, lo, hi| {
                    // SAFETY: bands are disjoint ranges
                    let gld = unsafe { glv.range(lo, hi) };
                    let gsd = unsafe { gsv.range(lo, hi) };
                    for (k, i) in (lo..hi).enumerate() {
                        let yl = ld[i] + beta * (ld[i] - lpd[i]);
                        let ys = sd[i] + beta * (sd[i] - spd[i]);
                        let half_resid = 0.5 * (yl + ys - md[i]);
                        gld[k] = yl - half_resid;
                        gsd[k] = ys - half_resid;
                    }
                    0.0
                });
            }
            std::mem::swap(&mut l_prev, &mut l);
            std::mem::swap(&mut s_prev, &mut s);
            // prox steps
            let (l_new, rank, next_hint) = svt_step(&gl, mu / 2.0, rank_hint, 0xA6 + k as u64);
            rank_hint = next_hint;
            l = l_new;
            {
                // S = shrink_{λμ/2}(G_S), written straight into S
                let sv = BandSlice::new(s.as_mut_slice());
                let gsd = gs.as_slice();
                let thresh = lambda * mu / 2.0;
                pool.run_bands(gsd.len(), &|_, lo, hi| {
                    // SAFETY: bands are disjoint ranges
                    let sd = unsafe { sv.range(lo, hi) };
                    crate::linalg::shrink_into(sd, &gsd[lo..hi], thresh);
                    0.0
                });
            }

            let t_next = (1.0 + (1.0 + 4.0 * t_k * t_k).sqrt()) / 2.0;
            t_prev = t_k;
            t_k = t_next;
            mu = (self.mu_decay * mu).max(mu_bar);
            iters = k + 1;

            // stopping: relative change of the iterate pair, accumulated
            // in one banded pass (partials summed in band order)
            let delta_sq = {
                let ld = l.as_slice();
                let lpd = l_prev.as_slice();
                let sd = s.as_slice();
                let spd = s_prev.as_slice();
                pool.run_bands(ld.len(), &|_, lo, hi| {
                    let mut acc = 0.0;
                    for i in lo..hi {
                        let dl = ld[i] - lpd[i];
                        let ds = sd[i] - spd[i];
                        acc += dl * dl + ds * ds;
                    }
                    acc
                })
            };
            let delta = delta_sq.sqrt() / m_norm;
            let err = truth.map(|p| crate::rpca::metrics::problem_error(p, &l, &s));
            history.push(IterRecord {
                iter: k,
                err,
                objective: rank as f64, // rank estimate doubles as telemetry
                grad_norm: delta,
                elapsed: start.elapsed().as_secs_f64(),
            });
            if delta < self.stop.tol {
                converged = true;
                break;
            }
        }

        let final_error = truth.map(|p| crate::rpca::metrics::problem_error(p, &l, &s));
        SolveResult {
            l,
            s,
            history,
            iterations: iters,
            converged,
            wall: start.elapsed(),
            final_error,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rpca::problem::ProblemSpec;

    #[test]
    fn spectral_norm_matches_svd() {
        let mut rng = crate::rng::Pcg64::new(71);
        let a = Mat::gaussian(20, 15, &mut rng);
        let exact = crate::linalg::singular_values(&a)[0];
        let est = spectral_norm(&a, 60);
        assert!((est - exact).abs() / exact < 1e-6, "{est} vs {exact}");
    }

    #[test]
    fn recovers_small_instance() {
        let p = ProblemSpec::square(60, 3, 0.05).generate(46);
        let solver = Apgm::new().with_stop(StopCriteria { max_iters: 300, tol: 1e-8 });
        let res = solver.solve(&p.observed, Some(&p));
        let err = res.final_error.unwrap();
        assert!(err < 1e-3, "relative error {err}");
    }

    #[test]
    fn error_decreases() {
        let p = ProblemSpec::square(50, 2, 0.05).generate(47);
        let solver = Apgm::new().with_stop(StopCriteria { max_iters: 120, tol: 0.0 });
        let res = solver.solve(&p.observed, Some(&p));
        let curve = res.error_curve();
        assert!(curve.last().unwrap().1 < 0.05 * curve.first().unwrap().1);
    }
}
