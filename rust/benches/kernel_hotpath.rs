//! Bench: hot-path kernels across the stack (§Perf of EXPERIMENTS.md).
//!
//! - L1: blocked gemm (the dominant flops), exact/randomized SVD
//!   (baseline cost), transport framing.
//! - L2/L3: the local epoch measured THREE ways at the paper's §4 shapes
//!   (m = n = 1000, p ∈ {5, 25}, J=3, K=2) —
//!     1. the historical allocating path (fresh buffers every sweep),
//!     2. the PR-1 multi-pass workspace path (zero-allocation but 4–6
//!        DRAM streams of the block per sweep; preserved as
//!        `factor::oracle`),
//!     3. the fused column-tile pipeline (one DRAM pass per sweep) at
//!        `--threads 1` (fusion alone) and `--threads 2` (fusion +
//!        panel parallelism).
//!   The fused and multi-pass rows carry both a GFLOP/s rate and an
//!   *effective bandwidth* (`effective_gb_per_s`): the block bytes the
//!   epoch logically moves under each traffic model divided by wall
//!   time — the number that shows fusion converting a bandwidth-bound
//!   kernel into a compute-bound one.
//! - RT: one PJRT client_update execution (artifact path), if artifacts
//!   are built.
//!
//! The run opens by probing the machine itself — peak FMA throughput of
//! the active dispatch (register-only chain loop) and streaming read
//! bandwidth (64 MiB sum) — and every compute row reports a
//! `roofline_fraction`: achieved GFLOP/s over `min(peak, AI·bandwidth)`
//! for that kernel's arithmetic intensity. A dedicated section times
//! each dispatched linalg entry point against its `*_scalar` oracle at
//! the §4 kernel shapes, so the SIMD speedup is tracked per kernel.
//!
//! Besides the human-readable table, each run writes a fresh snapshot to
//! `BENCH_kernel_hotpath.json` as `{host, records}`: `host` carries the
//! dispatch choice, detected CPU features, core count, and the two probe
//! numbers (so cross-machine records are interpretable); `records` is
//! the array of `{op, shape, ns_per_iter, gflops, effective_gb_per_s,
//! roofline_fraction}` rows (overwriting the previous run — the perf
//! trajectory accumulates as the file's history in git, diffed by
//! `scripts/bench_trend.sh`).

use std::collections::BTreeMap;

use dcf_pca::algorithms::factor::{inner_solve, oracle, ClientState, FactorHyper};
use dcf_pca::bench_util::{fmt_secs, Bencher, Table};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::linalg::{
    gemm, gram, gram_into, matmul, matmul_into, matmul_nt, matmul_nt_into, matmul_tn,
    matmul_tn_into, matvec, matvec_into, residual_shrink_into, ridge_solve_v, rsvd, shrink_sub_into,
    simd, svd_jacobi, Mat, RsvdParams, Workspace,
};
use dcf_pca::rng::Pcg64;
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::runtime::pool;
use dcf_pca::util::json::Json;

/// One machine-readable bench record.
struct Record {
    op: String,
    shape: String,
    ns_per_iter: f64,
    gflops: Option<f64>,
    effective_gb_per_s: Option<f64>,
    /// Achieved GFLOP/s over the kernel's roofline ceiling
    /// `min(peak_fma, AI · stream_bw)` — present on rows with a traffic
    /// model.
    roofline_fraction: Option<f64>,
}

impl Record {
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("op".to_string(), Json::Str(self.op.clone()));
        obj.insert("shape".to_string(), Json::Str(self.shape.clone()));
        obj.insert("ns_per_iter".to_string(), Json::Num(self.ns_per_iter));
        let opt = |v: Option<f64>| match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        };
        obj.insert("gflops".to_string(), opt(self.gflops));
        obj.insert("effective_gb_per_s".to_string(), opt(self.effective_gb_per_s));
        obj.insert("roofline_fraction".to_string(), opt(self.roofline_fraction));
        Json::Obj(obj)
    }
}

/// Host fingerprint for the JSON header: dispatch arm, features, cores,
/// and the measured machine ceilings the roofline fractions refer to.
fn host_header(peak_fma_gflops: f64, stream_gb_per_s: f64) -> Json {
    let features: Vec<Json> =
        simd::detected_features().into_iter().map(|f| Json::Str(f.to_string())).collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut obj = BTreeMap::new();
    obj.insert("dispatch".to_string(), Json::Str(simd::Dispatch::active().name().to_string()));
    obj.insert("forced_scalar".to_string(), Json::Bool(simd::forced_scalar()));
    obj.insert("features".to_string(), Json::Arr(features));
    obj.insert("cores".to_string(), Json::Num(cores as f64));
    obj.insert("peak_fma_gflops".to_string(), Json::Num(peak_fma_gflops));
    obj.insert("stream_gb_per_s".to_string(), Json::Num(stream_gb_per_s));
    Json::Obj(obj)
}

/// FLOPs of one local epoch: per sweep, the RHS accumulation and the
/// U·Vᵀ-for-shrink each cost 2mnp; the gradient pass costs another
/// 4mnp (residual + accumulate). Ridge solves and Gram terms are
/// O(np²)/O(mp²) — negligible at p ≪ min(m, n).
fn epoch_flops(m: usize, n: usize, p: usize, j: usize, k: usize) -> f64 {
    let mnp = (m * n * p) as f64;
    (k * j) as f64 * 4.0 * mnp + k as f64 * 4.0 * mnp
}

/// Block bytes one *fused* epoch moves (traffic model, 8 B/entry): each
/// sweep reads M once, reads S once, writes S once (3mn); each gradient
/// pass reads M and S (2mn). Factor-sized traffic (U, V) is L2-resident
/// and excluded on both sides of the comparison.
fn fused_epoch_bytes(m: usize, n: usize, j: usize, k: usize) -> f64 {
    let mn = (m * n) as f64 * 8.0;
    (k * j) as f64 * 3.0 * mn + k as f64 * 2.0 * mn
}

/// Block bytes one *multi-pass* epoch moves: per sweep — sub_into reads
/// M, S and writes resid (3mn), matmul_tn reads resid (mn), matmul_nt
/// rewrites resid (mn), residual_shrink reads M, resid and writes S
/// (3mn) — 8mn total; per gradient — residual_into writes resid, then
/// reads resid, S, M and rewrites it (5mn), matmul reads resid (mn) —
/// 6mn total.
fn multipass_epoch_bytes(m: usize, n: usize, j: usize, k: usize) -> f64 {
    let mn = (m * n) as f64 * 8.0;
    (k * j) as f64 * 8.0 * mn + k as f64 * 6.0 * mn
}

/// The pre-Workspace local epoch, reconstructed from the allocating
/// linalg twins: four to six full-size matrices are allocated and freed
/// per inner sweep (`gram`, `resid`, `rhs`, the ridge solve's internal
/// scratch, `uv`) plus the gradient temporaries and a per-epoch U clone —
/// exactly the traffic the Workspace refactor eliminated in PR 1.
fn allocating_local_epoch(
    u0: &Mat,
    m_block: &Mat,
    state: &mut ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
    eta: f64,
    k_local: usize,
) -> (Mat, f64) {
    let mut u = u0.clone();
    let mut grad_norm = 0.0;
    for _ in 0..k_local {
        for _ in 0..hyper.inner_sweeps {
            let g = gram(&u);
            let resid = m_block - &state.s;
            let rhs = matmul_tn(&u, &resid);
            state.v = ridge_solve_v(&g, &rhs, hyper.rho);
            let uv = matmul_nt(&u, &state.v);
            residual_shrink_into(&mut state.s, m_block, &uv, hyper.lambda);
        }
        let uv = matmul_nt(&u, &state.v);
        let resid = &(&uv + &state.s) - m_block;
        let mut grad = matmul(&resid, &state.v);
        grad.axpy(hyper.rho * n_frac, &u);
        grad_norm = grad.frob_norm();
        u.axpy(-eta, &grad);
    }
    // allocating curvature estimate (gram + per-iteration matvec Vecs),
    // matching what the pre-PR-1 kernel did after every epoch
    let g = gram(&state.v);
    let r = g.rows();
    let mut x = vec![1.0 / (r as f64).sqrt(); r];
    for _ in 0..20 {
        let y = matvec(&g, &x);
        let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < 1e-300 {
            break;
        }
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / norm;
        }
    }
    (u, grad_norm)
}

fn main() {
    let mut rng = Pcg64::new(1);
    let b = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(240) };
    let mut t = Table::new(&["kernel", "shape", "time (mean)", "GFLOP/s", "eff GB/s", "roofline"]);
    let mut records: Vec<Record> = Vec::new();

    // machine ceilings first — every roofline fraction below refers to
    // these two single-core probes, so rows from multi-threaded arms
    // deliberately omit the fraction
    let peak_gflops = simd::probe_peak_fma_gflops();
    let stream_gbs = simd::probe_stream_gb_per_s();
    println!(
        "host: dispatch={} peak_fma={peak_gflops:.1} GFLOP/s stream={stream_gbs:.1} GB/s",
        simd::Dispatch::active().name(),
    );

    // achieved GFLOP/s and its fraction of the kernel's roofline ceiling
    // min(peak, AI · bandwidth) under the given traffic model
    let roof = |flops: f64, bytes: f64, mean: f64| -> (f64, f64) {
        let gflops = flops / mean / 1e9;
        let ceiling = peak_gflops.min(stream_gbs * flops / bytes);
        (gflops, gflops / ceiling)
    };

    let push = |t: &mut Table,
                records: &mut Vec<Record>,
                op: &str,
                shape: &str,
                mean: f64,
                gflops: Option<f64>,
                gbs: Option<f64>,
                frac: Option<f64>| {
        let fmt_opt = |v: Option<f64>| v.map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into());
        let fmt_pct =
            |v: Option<f64>| v.map(|f| format!("{:.0}%", f * 100.0)).unwrap_or_else(|| "—".into());
        t.row(&[
            op.into(),
            shape.into(),
            fmt_secs(mean),
            fmt_opt(gflops),
            fmt_opt(gbs),
            fmt_pct(frac),
        ]);
        records.push(Record {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: mean * 1e9,
            gflops,
            effective_gb_per_s: gbs,
            roofline_fraction: frac,
        });
    };

    // times a dispatched entry point against its scalar oracle and emits
    // the pair as adjacent rows (`<op>` / `<op>_scalar`); the speedup
    // line is the tentpole's acceptance number
    let pair = |t: &mut Table,
                records: &mut Vec<Record>,
                op: &str,
                shape: &str,
                flops: f64,
                bytes: f64,
                dispatched: &mut dyn FnMut(),
                scalar: &mut dyn FnMut()| {
        let sd = b.run(&mut *dispatched);
        let ss = b.run(&mut *scalar);
        let (gf, frac) = roof(flops, bytes, sd.mean);
        push(t, records, op, shape, sd.mean, Some(gf), None, Some(frac));
        let op_s = format!("{op}_scalar");
        push(t, records, &op_s, shape, ss.mean, Some(flops / ss.mean / 1e9), None, None);
        println!("  {op} {shape}: {:.2}x vs scalar", ss.mean / sd.mean);
    };

    // gemm at the fig1 working shapes
    for &(m, k, n) in &[(500usize, 500usize, 25usize), (500, 25, 500), (1000, 1000, 50)] {
        let a = Mat::gaussian(m, k, &mut rng);
        let bm = Mat::gaussian(k, n, &mut rng);
        let stats = b.run(|| matmul(&a, &bm));
        let flops = 2.0 * (m * k * n) as f64;
        let bytes = 8.0 * (m * k + k * n + m * n) as f64;
        let (gflops, frac) = roof(flops, bytes, stats.mean);
        let shape = format!("{m}x{k}x{n}");
        push(&mut t, &mut records, "gemm", &shape, stats.mean, Some(gflops), None, Some(frac));
    }

    // U·Vᵀ (the residual product of every inner sweep)
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let v = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| matmul_nt(&u, &v));
        let flops = 2.0 * (500 * 25 * 500) as f64;
        let bytes = 8.0 * (500 * 25 + 500 * 25 + 500 * 500) as f64;
        let (gflops, frac) = roof(flops, bytes, stats.mean);
        let (op, shape) = ("gemm_nt (U·Vᵀ)", "500x25x500");
        push(&mut t, &mut records, op, shape, stats.mean, Some(gflops), None, Some(frac));
    }

    // dispatched kernels vs their scalar oracles at the §4 kernel shapes
    // (m = n = 1000, p ∈ {5, 25}) — the SIMD tentpole's headline: the
    // matmul family and gram_into should clear ≥2× on AVX2 hosts (a
    // forced-scalar run prints ~1.00× by construction)
    {
        println!("SIMD dispatch ({}) vs scalar oracle:", simd::Dispatch::active().name());
        let (m, n) = (1000usize, 1000usize);
        let a = Mat::gaussian(m, n, &mut rng);
        for &p_width in &[5usize, 25] {
            let bp = Mat::gaussian(n, p_width, &mut rng);
            let u = Mat::gaussian(m, p_width, &mut rng);
            let v = Mat::gaussian(n, p_width, &mut rng);
            let shape = format!("m=n=1000 p={p_width}");
            let flops = 2.0 * (m * n * p_width) as f64;

            let mut cd = Mat::zeros(m, p_width);
            let mut cs = Mat::zeros(m, p_width);
            pair(
                &mut t,
                &mut records,
                "matmul_into",
                &shape,
                flops,
                8.0 * (m * n + n * p_width + m * p_width) as f64,
                &mut || matmul_into(&mut cd, &a, &bp),
                &mut || gemm::matmul_acc_scalar(&mut cs, &a, &bp, 1.0, 0.0),
            );

            let mut td = Mat::zeros(p_width, n);
            let mut ts = Mat::zeros(p_width, n);
            pair(
                &mut t,
                &mut records,
                "matmul_tn_into",
                &shape,
                flops,
                8.0 * (m * p_width + m * n + p_width * n) as f64,
                &mut || matmul_tn_into(&mut td, &u, &a),
                &mut || gemm::matmul_tn_into_scalar(&mut ts, &u, &a),
            );

            let mut nd = Mat::zeros(m, n);
            let mut ns = Mat::zeros(m, n);
            pair(
                &mut t,
                &mut records,
                "matmul_nt_into",
                &shape,
                flops,
                8.0 * (m * p_width + n * p_width + m * n) as f64,
                &mut || matmul_nt_into(&mut nd, &u, &v),
                &mut || gemm::matmul_nt_into_scalar(&mut ns, &u, &v),
            );

            // gflops are nominal 2mp² for both arms (the scalar twin
            // exploits symmetry and does ~half the multiplies, so its
            // printed rate is a work rate, not a hardware rate)
            let mut gd = Mat::zeros(p_width, p_width);
            let mut gs = Mat::zeros(p_width, p_width);
            pair(
                &mut t,
                &mut records,
                "gram_into",
                &shape,
                2.0 * (m * p_width * p_width) as f64,
                8.0 * (m * p_width + p_width * p_width) as f64,
                &mut || gram_into(&mut gd, &u),
                &mut || gemm::gram_into_scalar(&mut gs, &u),
            );
        }

        // memory-bound rows: these ride the bandwidth ceiling, so the
        // roofline fraction is achieved traffic over the stream probe
        let x = vec![0.5f64; n];
        let mut yd = vec![0.0f64; m];
        let mut ys = vec![0.0f64; m];
        pair(
            &mut t,
            &mut records,
            "matvec_into",
            "1000x1000",
            2.0 * (m * n) as f64,
            8.0 * (m * n + n + m) as f64,
            &mut || matvec_into(&mut yd, &a, &x),
            &mut || gemm::matvec_into_scalar(&mut ys, &a, &x),
        );

        let a2 = Mat::gaussian(m, n, &mut rng);
        let mut dst_d = vec![0.0f64; m * n];
        let mut dst_s = vec![0.0f64; m * n];
        pair(
            &mut t,
            &mut records,
            "shrink_sub_into",
            "1000x1000",
            2.0 * (m * n) as f64,
            8.0 * 3.0 * (m * n) as f64,
            &mut || shrink_sub_into(&mut dst_d, a.as_slice(), a2.as_slice(), 0.1),
            &mut || simd::scalar::shrink_sub(&mut dst_s, a.as_slice(), a2.as_slice(), 0.1),
        );
    }

    // one inner solve at the paper's client shape (fused panel path)
    {
        let spec = ProblemSpec { m: 500, n: 50, rank: 25, sparsity: 0.05 };
        let p = spec.generate(7);
        let hyper = FactorHyper::default_for(500, 50, 25);
        let u = Mat::gaussian(500, 25, &mut rng);
        let mut state = ClientState::zeros(500, 50, 25);
        let mut ws = Workspace::new(500, 50, 25);
        let stats = b.run(|| {
            inner_solve(&u, &p.observed, &mut state, &hyper, pool::global(), &mut ws).unwrap()
        });
        push(
            &mut t,
            &mut records,
            "inner_solve (J=3)",
            "m=500 n_i=50 r=25",
            stats.mean,
            None,
            None,
            None,
        );
    }

    // THE headline comparison: allocating vs multi-pass workspace (PR 1,
    // preserved as factor::oracle) vs the fused column-tile epoch at
    // --threads 1 and 2 — m = n = 1000, p ∈ {5, 25}, J=3, K=2
    let (j_sweeps, k_local) = (3usize, 2usize);
    for &p_width in &[5usize, 25] {
        let spec = ProblemSpec { m: 1000, n: 1000, rank: p_width, sparsity: 0.05 };
        let prob = spec.generate(11);
        let hyper = FactorHyper::default_for(1000, 1000, p_width);
        assert_eq!(hyper.inner_sweeps, j_sweeps, "flop/byte models assume J = inner_sweeps");
        let u0 = Mat::gaussian(1000, p_width, &mut rng);
        let shape = format!("m=n=1000 p={p_width} J={j_sweeps} K={k_local}");
        let flops = epoch_flops(1000, 1000, p_width, j_sweeps, k_local);

        let mut state_a = ClientState::zeros(1000, 1000, p_width);
        let stats_alloc = b.run(|| {
            allocating_local_epoch(&u0, &prob.observed, &mut state_a, &hyper, 1.0, 1e-3, k_local)
        });
        push(
            &mut t,
            &mut records,
            "local_epoch (allocating)",
            &shape,
            stats_alloc.mean,
            Some(flops / stats_alloc.mean / 1e9),
            None,
            None,
        );

        // PR-1 multi-pass workspace epoch (the ≥1.8×/≥1.2× baseline)
        let mut state_mp = ClientState::zeros(1000, 1000, p_width);
        let mut ows = oracle::MultipassWorkspace::new(1000, 1000, p_width);
        let mut u_mp = u0.clone();
        let stats_mp = b.run(|| {
            // restart U from u0 each sample so every arm measures the
            // identical numerical work; only (V, S) warm-start across
            // samples, in all arms
            u_mp.copy_from(&u0);
            oracle::local_epoch(
                &mut u_mp,
                &prob.observed,
                &mut state_mp,
                &hyper,
                1.0,
                1e-3,
                k_local,
                &mut ows,
            )
        });
        let mp_bytes = multipass_epoch_bytes(1000, 1000, j_sweeps, k_local);
        push(
            &mut t,
            &mut records,
            "local_epoch (multipass)",
            &shape,
            stats_mp.mean,
            Some(flops / stats_mp.mean / 1e9),
            Some(mp_bytes / stats_mp.mean / 1e9),
            None,
        );

        // fused column-tile epoch, threads ∈ {1, 2}
        let fused_bytes = fused_epoch_bytes(1000, 1000, j_sweeps, k_local);
        let mut fused_means = Vec::new();
        for threads in [1usize, 2] {
            let kernel = NativeKernel::with_threads(threads);
            let mut state_f = ClientState::zeros(1000, 1000, p_width);
            let mut ws = Workspace::new(1000, 1000, p_width);
            let mut u_f = u0.clone();
            let stats_f = b.run(|| {
                u_f.copy_from(&u0);
                kernel
                    .local_epoch(
                        &mut u_f,
                        &prob.observed,
                        &mut state_f,
                        &hyper,
                        1.0,
                        1e-3,
                        k_local,
                        &mut ws,
                    )
                    .unwrap()
            });
            push(
                &mut t,
                &mut records,
                &format!("local_epoch (fused t{threads})"),
                &shape,
                stats_f.mean,
                Some(flops / stats_f.mean / 1e9),
                Some(fused_bytes / stats_f.mean / 1e9),
                // single-core ceilings only apply to the t1 arm
                if threads == 1 { Some(roof(flops, fused_bytes, stats_f.mean).1) } else { None },
            );
            fused_means.push(stats_f.mean);
        }

        println!(
            "local epoch at {shape}: fused t1 {:.2}x, fused t2 {:.2}x vs multipass \
             ({:.2}x vs allocating)",
            stats_mp.mean / fused_means[0],
            stats_mp.mean / fused_means[1],
            stats_alloc.mean / fused_means[1],
        );
    }

    // SVD costs (what the baselines pay per iteration)
    {
        let a = Mat::gaussian(200, 200, &mut rng);
        let stats = b.run(|| svd_jacobi(&a));
        push(&mut t, &mut records, "svd_jacobi", "200x200", stats.mean, None, None, None);
        let big = Mat::gaussian(1000, 1000, &mut rng);
        let stats = b.run(|| rsvd(&big, RsvdParams::new(60)));
        push(&mut t, &mut records, "rsvd k=60", "1000x1000", stats.mean, None, None, None);
    }

    // transport framing round-trip
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| {
            let msg = dcf_pca::coordinator::protocol::ToClient::Round {
                round: 0,
                k_local: 2,
                eta: 0.1,
                u: u.clone(),
            };
            let bytes = msg.encode();
            dcf_pca::coordinator::protocol::ToClient::decode(&bytes).unwrap()
        });
        let mbps = (500.0 * 25.0 * 8.0) / stats.mean / 1e6;
        t.row(&[
            "protocol enc+dec".into(),
            "U 500x25".into(),
            fmt_secs(stats.mean),
            format!("{mbps:.0} MB/s"),
            "—".into(),
            "—".into(),
        ]);
        records.push(Record {
            op: "protocol enc+dec".to_string(),
            shape: "U 500x25".to_string(),
            ns_per_iter: stats.mean * 1e9,
            gflops: None,
            effective_gb_per_s: None,
            roofline_fraction: None,
        });
    }

    // PJRT artifact execution (if built and the runtime is available)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        match dcf_pca::runtime::PjrtKernel::load("artifacts") {
            Ok(kernel) => {
                let spec = ProblemSpec { m: 64, n: 32, rank: 4, sparsity: 0.05 };
                let p = spec.generate(9);
                let hyper = FactorHyper::default_for(64, 32, 4);
                let u0 = Mat::gaussian(64, 4, &mut rng);
                let mut state = ClientState::zeros(64, 32, 4);
                let mut ws = Workspace::new(64, 32, 4);
                let mut u = u0.clone();
                // warm compile
                kernel
                    .local_epoch(&mut u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2, &mut ws)
                    .unwrap();
                let stats = b.run(|| {
                    let mut u = u0.clone();
                    kernel
                        .local_epoch(&mut u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2, &mut ws)
                        .unwrap()
                });
                push(
                    &mut t,
                    &mut records,
                    "pjrt client_update",
                    "m=64 n_i=32 r=4 K=2",
                    stats.mean,
                    None,
                    None,
                    None,
                );
            }
            Err(err) => println!("(PJRT unavailable — skipping artifact rows: {err})"),
        }
    } else {
        println!("(artifacts not built — skipping PJRT row; run `make artifacts`)");
    }

    println!("\nkernel hot-path timings:");
    t.print();

    let mut top = BTreeMap::new();
    top.insert("host".to_string(), host_header(peak_gflops, stream_gbs));
    top.insert("records".to_string(), Json::Arr(records.iter().map(Record::to_json).collect()));
    let json = Json::Obj(top);
    let out_path = "BENCH_kernel_hotpath.json";
    match std::fs::write(out_path, format!("{json}\n")) {
        Ok(()) => println!("\nmachine-readable results written to {out_path}"),
        Err(err) => eprintln!("could not write {out_path}: {err}"),
    }
}
