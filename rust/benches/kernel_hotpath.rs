//! Bench: hot-path kernels across the stack (§Perf of EXPERIMENTS.md).
//!
//! - L1: blocked gemm (the dominant flops), exact/randomized SVD
//!   (baseline cost), transport framing.
//! - L2/L3: the local epoch measured THREE ways at the paper's §4 shapes
//!   (m = n = 1000, p ∈ {5, 25}, J=3, K=2) —
//!     1. the historical allocating path (fresh buffers every sweep),
//!     2. the PR-1 multi-pass workspace path (zero-allocation but 4–6
//!        DRAM streams of the block per sweep; preserved as
//!        `factor::oracle`),
//!     3. the fused column-tile pipeline (one DRAM pass per sweep) at
//!        `--threads 1` (fusion alone) and `--threads 2` (fusion +
//!        panel parallelism).
//!   The fused and multi-pass rows carry both a GFLOP/s rate and an
//!   *effective bandwidth* (`effective_gb_per_s`): the block bytes the
//!   epoch logically moves under each traffic model divided by wall
//!   time — the number that shows fusion converting a bandwidth-bound
//!   kernel into a compute-bound one.
//! - RT: one PJRT client_update execution (artifact path), if artifacts
//!   are built.
//!
//! Besides the human-readable table, each run writes a fresh snapshot of
//! `{op, shape, ns_per_iter, gflops, effective_gb_per_s}` records to
//! `BENCH_kernel_hotpath.json` (overwriting the previous run — the perf
//! trajectory accumulates as the file's history in git).

use std::collections::BTreeMap;

use dcf_pca::algorithms::factor::{inner_solve, oracle, ClientState, FactorHyper};
use dcf_pca::bench_util::{fmt_secs, Bencher, Table};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::linalg::{
    gram, matmul, matmul_nt, matmul_tn, matvec, residual_shrink_into, ridge_solve_v, rsvd,
    svd_jacobi, Mat, RsvdParams, Workspace,
};
use dcf_pca::rng::Pcg64;
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::runtime::pool;
use dcf_pca::util::json::Json;

/// One machine-readable bench record.
struct Record {
    op: String,
    shape: String,
    ns_per_iter: f64,
    gflops: Option<f64>,
    effective_gb_per_s: Option<f64>,
}

impl Record {
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("op".to_string(), Json::Str(self.op.clone()));
        obj.insert("shape".to_string(), Json::Str(self.shape.clone()));
        obj.insert("ns_per_iter".to_string(), Json::Num(self.ns_per_iter));
        let opt = |v: Option<f64>| match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        };
        obj.insert("gflops".to_string(), opt(self.gflops));
        obj.insert("effective_gb_per_s".to_string(), opt(self.effective_gb_per_s));
        Json::Obj(obj)
    }
}

/// FLOPs of one local epoch: per sweep, the RHS accumulation and the
/// U·Vᵀ-for-shrink each cost 2mnp; the gradient pass costs another
/// 4mnp (residual + accumulate). Ridge solves and Gram terms are
/// O(np²)/O(mp²) — negligible at p ≪ min(m, n).
fn epoch_flops(m: usize, n: usize, p: usize, j: usize, k: usize) -> f64 {
    let mnp = (m * n * p) as f64;
    (k * j) as f64 * 4.0 * mnp + k as f64 * 4.0 * mnp
}

/// Block bytes one *fused* epoch moves (traffic model, 8 B/entry): each
/// sweep reads M once, reads S once, writes S once (3mn); each gradient
/// pass reads M and S (2mn). Factor-sized traffic (U, V) is L2-resident
/// and excluded on both sides of the comparison.
fn fused_epoch_bytes(m: usize, n: usize, j: usize, k: usize) -> f64 {
    let mn = (m * n) as f64 * 8.0;
    (k * j) as f64 * 3.0 * mn + k as f64 * 2.0 * mn
}

/// Block bytes one *multi-pass* epoch moves: per sweep — sub_into reads
/// M, S and writes resid (3mn), matmul_tn reads resid (mn), matmul_nt
/// rewrites resid (mn), residual_shrink reads M, resid and writes S
/// (3mn) — 8mn total; per gradient — residual_into writes resid, then
/// reads resid, S, M and rewrites it (5mn), matmul reads resid (mn) —
/// 6mn total.
fn multipass_epoch_bytes(m: usize, n: usize, j: usize, k: usize) -> f64 {
    let mn = (m * n) as f64 * 8.0;
    (k * j) as f64 * 8.0 * mn + k as f64 * 6.0 * mn
}

/// The pre-Workspace local epoch, reconstructed from the allocating
/// linalg twins: four to six full-size matrices are allocated and freed
/// per inner sweep (`gram`, `resid`, `rhs`, the ridge solve's internal
/// scratch, `uv`) plus the gradient temporaries and a per-epoch U clone —
/// exactly the traffic the Workspace refactor eliminated in PR 1.
fn allocating_local_epoch(
    u0: &Mat,
    m_block: &Mat,
    state: &mut ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
    eta: f64,
    k_local: usize,
) -> (Mat, f64) {
    let mut u = u0.clone();
    let mut grad_norm = 0.0;
    for _ in 0..k_local {
        for _ in 0..hyper.inner_sweeps {
            let g = gram(&u);
            let resid = m_block - &state.s;
            let rhs = matmul_tn(&u, &resid);
            state.v = ridge_solve_v(&g, &rhs, hyper.rho);
            let uv = matmul_nt(&u, &state.v);
            residual_shrink_into(&mut state.s, m_block, &uv, hyper.lambda);
        }
        let uv = matmul_nt(&u, &state.v);
        let resid = &(&uv + &state.s) - m_block;
        let mut grad = matmul(&resid, &state.v);
        grad.axpy(hyper.rho * n_frac, &u);
        grad_norm = grad.frob_norm();
        u.axpy(-eta, &grad);
    }
    // allocating curvature estimate (gram + per-iteration matvec Vecs),
    // matching what the pre-PR-1 kernel did after every epoch
    let g = gram(&state.v);
    let r = g.rows();
    let mut x = vec![1.0 / (r as f64).sqrt(); r];
    for _ in 0..20 {
        let y = matvec(&g, &x);
        let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < 1e-300 {
            break;
        }
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / norm;
        }
    }
    (u, grad_norm)
}

fn main() {
    let mut rng = Pcg64::new(1);
    let b = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(240) };
    let mut t = Table::new(&["kernel", "shape", "time (mean)", "GFLOP/s", "eff GB/s"]);
    let mut records: Vec<Record> = Vec::new();

    let push = |t: &mut Table,
                records: &mut Vec<Record>,
                op: &str,
                shape: &str,
                mean: f64,
                gflops: Option<f64>,
                gbs: Option<f64>| {
        let fmt_opt = |v: Option<f64>| v.map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into());
        t.row(&[op.into(), shape.into(), fmt_secs(mean), fmt_opt(gflops), fmt_opt(gbs)]);
        records.push(Record {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: mean * 1e9,
            gflops,
            effective_gb_per_s: gbs,
        });
    };

    // gemm at the fig1 working shapes
    for &(m, k, n) in &[(500usize, 500usize, 25usize), (500, 25, 500), (1000, 1000, 50)] {
        let a = Mat::gaussian(m, k, &mut rng);
        let bm = Mat::gaussian(k, n, &mut rng);
        let stats = b.run(|| matmul(&a, &bm));
        let gflops = 2.0 * (m * k * n) as f64 / stats.mean / 1e9;
        push(&mut t, &mut records, "gemm", &format!("{m}x{k}x{n}"), stats.mean, Some(gflops), None);
    }

    // U·Vᵀ (the residual product of every inner sweep)
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let v = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| matmul_nt(&u, &v));
        let gflops = 2.0 * (500 * 25 * 500) as f64 / stats.mean / 1e9;
        let (op, shape) = ("gemm_nt (U·Vᵀ)", "500x25x500");
        push(&mut t, &mut records, op, shape, stats.mean, Some(gflops), None);
    }

    // one inner solve at the paper's client shape (fused panel path)
    {
        let spec = ProblemSpec { m: 500, n: 50, rank: 25, sparsity: 0.05 };
        let p = spec.generate(7);
        let hyper = FactorHyper::default_for(500, 50, 25);
        let u = Mat::gaussian(500, 25, &mut rng);
        let mut state = ClientState::zeros(500, 50, 25);
        let mut ws = Workspace::new(500, 50, 25);
        let stats = b.run(|| {
            inner_solve(&u, &p.observed, &mut state, &hyper, pool::global(), &mut ws).unwrap()
        });
        push(
            &mut t,
            &mut records,
            "inner_solve (J=3)",
            "m=500 n_i=50 r=25",
            stats.mean,
            None,
            None,
        );
    }

    // THE headline comparison: allocating vs multi-pass workspace (PR 1,
    // preserved as factor::oracle) vs the fused column-tile epoch at
    // --threads 1 and 2 — m = n = 1000, p ∈ {5, 25}, J=3, K=2
    let (j_sweeps, k_local) = (3usize, 2usize);
    for &p_width in &[5usize, 25] {
        let spec = ProblemSpec { m: 1000, n: 1000, rank: p_width, sparsity: 0.05 };
        let prob = spec.generate(11);
        let hyper = FactorHyper::default_for(1000, 1000, p_width);
        assert_eq!(hyper.inner_sweeps, j_sweeps, "flop/byte models assume J = inner_sweeps");
        let u0 = Mat::gaussian(1000, p_width, &mut rng);
        let shape = format!("m=n=1000 p={p_width} J={j_sweeps} K={k_local}");
        let flops = epoch_flops(1000, 1000, p_width, j_sweeps, k_local);

        let mut state_a = ClientState::zeros(1000, 1000, p_width);
        let stats_alloc = b.run(|| {
            allocating_local_epoch(&u0, &prob.observed, &mut state_a, &hyper, 1.0, 1e-3, k_local)
        });
        push(
            &mut t,
            &mut records,
            "local_epoch (allocating)",
            &shape,
            stats_alloc.mean,
            Some(flops / stats_alloc.mean / 1e9),
            None,
        );

        // PR-1 multi-pass workspace epoch (the ≥1.8×/≥1.2× baseline)
        let mut state_mp = ClientState::zeros(1000, 1000, p_width);
        let mut ows = oracle::MultipassWorkspace::new(1000, 1000, p_width);
        let mut u_mp = u0.clone();
        let stats_mp = b.run(|| {
            // restart U from u0 each sample so every arm measures the
            // identical numerical work; only (V, S) warm-start across
            // samples, in all arms
            u_mp.copy_from(&u0);
            oracle::local_epoch(
                &mut u_mp,
                &prob.observed,
                &mut state_mp,
                &hyper,
                1.0,
                1e-3,
                k_local,
                &mut ows,
            )
        });
        let mp_bytes = multipass_epoch_bytes(1000, 1000, j_sweeps, k_local);
        push(
            &mut t,
            &mut records,
            "local_epoch (multipass)",
            &shape,
            stats_mp.mean,
            Some(flops / stats_mp.mean / 1e9),
            Some(mp_bytes / stats_mp.mean / 1e9),
        );

        // fused column-tile epoch, threads ∈ {1, 2}
        let fused_bytes = fused_epoch_bytes(1000, 1000, j_sweeps, k_local);
        let mut fused_means = Vec::new();
        for threads in [1usize, 2] {
            let kernel = NativeKernel::with_threads(threads);
            let mut state_f = ClientState::zeros(1000, 1000, p_width);
            let mut ws = Workspace::new(1000, 1000, p_width);
            let mut u_f = u0.clone();
            let stats_f = b.run(|| {
                u_f.copy_from(&u0);
                kernel
                    .local_epoch(
                        &mut u_f,
                        &prob.observed,
                        &mut state_f,
                        &hyper,
                        1.0,
                        1e-3,
                        k_local,
                        &mut ws,
                    )
                    .unwrap()
            });
            push(
                &mut t,
                &mut records,
                &format!("local_epoch (fused t{threads})"),
                &shape,
                stats_f.mean,
                Some(flops / stats_f.mean / 1e9),
                Some(fused_bytes / stats_f.mean / 1e9),
            );
            fused_means.push(stats_f.mean);
        }

        println!(
            "local epoch at {shape}: fused t1 {:.2}x, fused t2 {:.2}x vs multipass \
             ({:.2}x vs allocating)",
            stats_mp.mean / fused_means[0],
            stats_mp.mean / fused_means[1],
            stats_alloc.mean / fused_means[1],
        );
    }

    // SVD costs (what the baselines pay per iteration)
    {
        let a = Mat::gaussian(200, 200, &mut rng);
        let stats = b.run(|| svd_jacobi(&a));
        push(&mut t, &mut records, "svd_jacobi", "200x200", stats.mean, None, None);
        let big = Mat::gaussian(1000, 1000, &mut rng);
        let stats = b.run(|| rsvd(&big, RsvdParams::new(60)));
        push(&mut t, &mut records, "rsvd k=60", "1000x1000", stats.mean, None, None);
    }

    // transport framing round-trip
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| {
            let msg = dcf_pca::coordinator::protocol::ToClient::Round {
                round: 0,
                k_local: 2,
                eta: 0.1,
                u: u.clone(),
            };
            let bytes = msg.encode();
            dcf_pca::coordinator::protocol::ToClient::decode(&bytes).unwrap()
        });
        let mbps = (500.0 * 25.0 * 8.0) / stats.mean / 1e6;
        t.row(&[
            "protocol enc+dec".into(),
            "U 500x25".into(),
            fmt_secs(stats.mean),
            format!("{mbps:.0} MB/s"),
            "—".into(),
        ]);
        records.push(Record {
            op: "protocol enc+dec".to_string(),
            shape: "U 500x25".to_string(),
            ns_per_iter: stats.mean * 1e9,
            gflops: None,
            effective_gb_per_s: None,
        });
    }

    // PJRT artifact execution (if built and the runtime is available)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        match dcf_pca::runtime::PjrtKernel::load("artifacts") {
            Ok(kernel) => {
                let spec = ProblemSpec { m: 64, n: 32, rank: 4, sparsity: 0.05 };
                let p = spec.generate(9);
                let hyper = FactorHyper::default_for(64, 32, 4);
                let u0 = Mat::gaussian(64, 4, &mut rng);
                let mut state = ClientState::zeros(64, 32, 4);
                let mut ws = Workspace::new(64, 32, 4);
                let mut u = u0.clone();
                // warm compile
                kernel
                    .local_epoch(&mut u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2, &mut ws)
                    .unwrap();
                let stats = b.run(|| {
                    let mut u = u0.clone();
                    kernel
                        .local_epoch(&mut u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2, &mut ws)
                        .unwrap()
                });
                push(
                    &mut t,
                    &mut records,
                    "pjrt client_update",
                    "m=64 n_i=32 r=4 K=2",
                    stats.mean,
                    None,
                    None,
                );
            }
            Err(err) => println!("(PJRT unavailable — skipping artifact rows: {err})"),
        }
    } else {
        println!("(artifacts not built — skipping PJRT row; run `make artifacts`)");
    }

    println!("\nkernel hot-path timings:");
    t.print();

    let json = Json::Arr(records.iter().map(Record::to_json).collect());
    let out_path = "BENCH_kernel_hotpath.json";
    match std::fs::write(out_path, format!("{json}\n")) {
        Ok(()) => println!("\nmachine-readable results written to {out_path}"),
        Err(err) => eprintln!("could not write {out_path}: {err}"),
    }
}
