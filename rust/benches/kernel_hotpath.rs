//! Bench: hot-path kernels across the stack (§Perf of EXPERIMENTS.md).
//!
//! - L3-native: blocked gemm (the dominant flops), inner sweep, local
//!   epoch, exact/randomized SVD (baseline cost), transport framing.
//! - RT: one PJRT client_update execution (artifact path), if artifacts
//!   are built.

use dcf_pca::algorithms::factor::{inner_solve, ClientState, FactorHyper};
use dcf_pca::bench_util::{fmt_secs, Bencher, Table};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::linalg::{matmul, matmul_nt, rsvd, svd_jacobi, Mat, RsvdParams};
use dcf_pca::rng::Pcg64;
use dcf_pca::rpca::problem::ProblemSpec;

fn main() {
    let mut rng = Pcg64::new(1);
    let b = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(240) };
    let mut t = Table::new(&["kernel", "shape", "time (mean)", "GFLOP/s"]);

    // gemm at the fig1 working shapes
    for &(m, k, n) in &[(500usize, 500usize, 25usize), (500, 25, 500), (1000, 1000, 50)] {
        let a = Mat::gaussian(m, k, &mut rng);
        let bm = Mat::gaussian(k, n, &mut rng);
        let stats = b.run(|| matmul(&a, &bm));
        let gflops = 2.0 * (m * k * n) as f64 / stats.mean / 1e9;
        t.row(&[
            "gemm".into(),
            format!("{m}x{k}x{n}"),
            fmt_secs(stats.mean),
            format!("{gflops:.2}"),
        ]);
    }

    // U·Vᵀ (the residual product of every inner sweep)
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let v = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| matmul_nt(&u, &v));
        let gflops = 2.0 * (500 * 25 * 500) as f64 / stats.mean / 1e9;
        t.row(&["gemm_nt (U·Vᵀ)".into(), "500x25x500".into(), fmt_secs(stats.mean), format!("{gflops:.2}")]);
    }

    // one inner solve + one full local epoch at the paper's client shape
    {
        let spec = ProblemSpec { m: 500, n: 50, rank: 25, sparsity: 0.05 };
        let p = spec.generate(7);
        let hyper = FactorHyper::default_for(500, 50, 25);
        let u = Mat::gaussian(500, 25, &mut rng);
        let mut state = ClientState::zeros(500, 50, 25);
        let stats = b.run(|| inner_solve(&u, &p.observed, &mut state, &hyper));
        t.row(&["inner_solve (J=3)".into(), "m=500 n_i=50 r=25".into(), fmt_secs(stats.mean), "—".into()]);
        let mut state2 = ClientState::zeros(500, 50, 25);
        let stats = b.run(|| {
            NativeKernel
                .local_epoch(&u, &p.observed, &mut state2, &hyper, 0.1, 1e-3, 2)
                .unwrap()
        });
        t.row(&["local_epoch (K=2)".into(), "m=500 n_i=50 r=25".into(), fmt_secs(stats.mean), "—".into()]);
    }

    // SVD costs (what the baselines pay per iteration)
    {
        let a = Mat::gaussian(200, 200, &mut rng);
        let stats = b.run(|| svd_jacobi(&a));
        t.row(&["svd_jacobi".into(), "200x200".into(), fmt_secs(stats.mean), "—".into()]);
        let big = Mat::gaussian(1000, 1000, &mut rng);
        let stats = b.run(|| rsvd(&big, RsvdParams::new(60)));
        t.row(&["rsvd k=60".into(), "1000x1000".into(), fmt_secs(stats.mean), "—".into()]);
    }

    // transport framing round-trip
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| {
            let msg = dcf_pca::coordinator::protocol::ToClient::Round {
                round: 0,
                k_local: 2,
                eta: 0.1,
                u: u.clone(),
            };
            let bytes = msg.encode();
            dcf_pca::coordinator::protocol::ToClient::decode(&bytes).unwrap()
        });
        let mbps = (500.0 * 25.0 * 8.0) / stats.mean / 1e6;
        t.row(&["protocol enc+dec".into(), "U 500x25".into(), fmt_secs(stats.mean), format!("{mbps:.0} MB/s")]);
    }

    // PJRT artifact execution (if built)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        let kernel = dcf_pca::runtime::PjrtKernel::load("artifacts").unwrap();
        let spec = ProblemSpec { m: 64, n: 32, rank: 4, sparsity: 0.05 };
        let p = spec.generate(9);
        let hyper = FactorHyper::default_for(64, 32, 4);
        let u = Mat::gaussian(64, 4, &mut rng);
        let mut state = ClientState::zeros(64, 32, 4);
        // warm compile
        kernel.local_epoch(&u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2).unwrap();
        let stats = b.run(|| {
            kernel
                .local_epoch(&u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2)
                .unwrap()
        });
        t.row(&["pjrt client_update".into(), "m=64 n_i=32 r=4 K=2".into(), fmt_secs(stats.mean), "—".into()]);
        let mut state3 = ClientState::zeros(64, 32, 4);
        let stats = b.run(|| {
            NativeKernel
                .local_epoch(&u, &p.observed, &mut state3, &hyper, 0.5, 1e-3, 2)
                .unwrap()
        });
        t.row(&["native client_update".into(), "m=64 n_i=32 r=4 K=2".into(), fmt_secs(stats.mean), "—".into()]);
    } else {
        println!("(artifacts not built — skipping PJRT row; run `make artifacts`)");
    }

    println!("\nkernel hot-path timings:");
    t.print();
}
