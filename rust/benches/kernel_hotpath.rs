//! Bench: hot-path kernels across the stack (§Perf of EXPERIMENTS.md).
//!
//! - L1: blocked gemm (the dominant flops), exact/randomized SVD
//!   (baseline cost), transport framing.
//! - L2/L3: inner solve and the full local epoch, measured BOTH ways —
//!   the historical allocating path (fresh buffers every sweep,
//!   reconstructed here from the allocating linalg twins) against the
//!   `Workspace`-based zero-allocation path the kernels now use — at the
//!   paper's §4 shapes (m = n = 1000, p ∈ {5, 25}).
//! - RT: one PJRT client_update execution (artifact path), if artifacts
//!   are built.
//!
//! Besides the human-readable table, each run writes a fresh snapshot
//! of `{op, shape, ns_per_iter, gflops}` records to
//! `BENCH_kernel_hotpath.json` (overwriting the previous run — the
//! perf trajectory accumulates as the file's history in git).

use std::collections::BTreeMap;

use dcf_pca::algorithms::factor::{inner_solve, ClientState, FactorHyper};
use dcf_pca::bench_util::{fmt_secs, Bencher, Table};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::linalg::{
    gram, matmul, matmul_nt, matmul_tn, matvec, residual_shrink_into, ridge_solve_v, rsvd,
    svd_jacobi, Mat, RsvdParams, Workspace,
};
use dcf_pca::rng::Pcg64;
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::util::json::Json;

/// One machine-readable bench record.
struct Record {
    op: String,
    shape: String,
    ns_per_iter: f64,
    gflops: Option<f64>,
}

impl Record {
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("op".to_string(), Json::Str(self.op.clone()));
        obj.insert("shape".to_string(), Json::Str(self.shape.clone()));
        obj.insert("ns_per_iter".to_string(), Json::Num(self.ns_per_iter));
        obj.insert(
            "gflops".to_string(),
            match self.gflops {
                Some(g) => Json::Num(g),
                None => Json::Null,
            },
        );
        Json::Obj(obj)
    }
}

/// The pre-Workspace local epoch, reconstructed from the allocating
/// linalg twins: four to six full-size matrices are allocated and freed
/// per inner sweep (`gram`, `resid`, `rhs`, the ridge solve's internal
/// scratch, `uv`) plus the gradient temporaries and a per-epoch U clone —
/// exactly the traffic the Workspace refactor eliminates.
fn allocating_local_epoch(
    u0: &Mat,
    m_block: &Mat,
    state: &mut ClientState,
    hyper: &FactorHyper,
    n_frac: f64,
    eta: f64,
    k_local: usize,
) -> (Mat, f64) {
    let mut u = u0.clone();
    let mut grad_norm = 0.0;
    for _ in 0..k_local {
        for _ in 0..hyper.inner_sweeps {
            let g = gram(&u);
            let resid = m_block - &state.s;
            let rhs = matmul_tn(&u, &resid);
            state.v = ridge_solve_v(&g, &rhs, hyper.rho);
            let uv = matmul_nt(&u, &state.v);
            residual_shrink_into(&mut state.s, m_block, &uv, hyper.lambda);
        }
        let uv = matmul_nt(&u, &state.v);
        let resid = &(&uv + &state.s) - m_block;
        let mut grad = matmul(&resid, &state.v);
        grad.axpy(hyper.rho * n_frac, &u);
        grad_norm = grad.frob_norm();
        u.axpy(-eta, &grad);
    }
    // allocating curvature estimate (gram + per-iteration matvec Vecs),
    // matching what the old kernel did after every epoch
    let g = gram(&state.v);
    let r = g.rows();
    let mut x = vec![1.0 / (r as f64).sqrt(); r];
    for _ in 0..20 {
        let y = matvec(&g, &x);
        let norm = y.iter().map(|v| v * v).sum::<f64>().sqrt();
        if norm < 1e-300 {
            break;
        }
        for (xi, yi) in x.iter_mut().zip(&y) {
            *xi = yi / norm;
        }
    }
    (u, grad_norm)
}

fn main() {
    let mut rng = Pcg64::new(1);
    let b = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(240) };
    let mut t = Table::new(&["kernel", "shape", "time (mean)", "GFLOP/s"]);
    let mut records: Vec<Record> = Vec::new();

    let push = |t: &mut Table, records: &mut Vec<Record>, op: &str, shape: &str, mean: f64, gflops: Option<f64>| {
        t.row(&[
            op.into(),
            shape.into(),
            fmt_secs(mean),
            gflops.map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into()),
        ]);
        records.push(Record {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: mean * 1e9,
            gflops,
        });
    };

    // gemm at the fig1 working shapes
    for &(m, k, n) in &[(500usize, 500usize, 25usize), (500, 25, 500), (1000, 1000, 50)] {
        let a = Mat::gaussian(m, k, &mut rng);
        let bm = Mat::gaussian(k, n, &mut rng);
        let stats = b.run(|| matmul(&a, &bm));
        let gflops = 2.0 * (m * k * n) as f64 / stats.mean / 1e9;
        push(&mut t, &mut records, "gemm", &format!("{m}x{k}x{n}"), stats.mean, Some(gflops));
    }

    // U·Vᵀ (the residual product of every inner sweep)
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let v = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| matmul_nt(&u, &v));
        let gflops = 2.0 * (500 * 25 * 500) as f64 / stats.mean / 1e9;
        push(&mut t, &mut records, "gemm_nt (U·Vᵀ)", "500x25x500", stats.mean, Some(gflops));
    }

    // one inner solve at the paper's client shape (workspace path)
    {
        let spec = ProblemSpec { m: 500, n: 50, rank: 25, sparsity: 0.05 };
        let p = spec.generate(7);
        let hyper = FactorHyper::default_for(500, 50, 25);
        let u = Mat::gaussian(500, 25, &mut rng);
        let mut state = ClientState::zeros(500, 50, 25);
        let mut ws = Workspace::new(500, 50, 25);
        let stats = b.run(|| inner_solve(&u, &p.observed, &mut state, &hyper, &mut ws));
        push(&mut t, &mut records, "inner_solve (J=3)", "m=500 n_i=50 r=25", stats.mean, None);
    }

    // THE headline comparison: allocating vs workspace local epoch at the
    // paper's §4 shapes — m = n = 1000, p ∈ {5, 25}, J=3, K=2
    for &p_width in &[5usize, 25] {
        let spec = ProblemSpec { m: 1000, n: 1000, rank: p_width, sparsity: 0.05 };
        let prob = spec.generate(11);
        let hyper = FactorHyper::default_for(1000, 1000, p_width);
        let u0 = Mat::gaussian(1000, p_width, &mut rng);
        let shape = format!("m=n=1000 p={p_width} J=3 K=2");

        let mut state_a = ClientState::zeros(1000, 1000, p_width);
        let stats_alloc = b.run(|| {
            allocating_local_epoch(&u0, &prob.observed, &mut state_a, &hyper, 1.0, 1e-3, 2)
        });
        push(&mut t, &mut records, "local_epoch (allocating)", &shape, stats_alloc.mean, None);

        let mut state_b = ClientState::zeros(1000, 1000, p_width);
        let mut ws = Workspace::new(1000, 1000, p_width);
        let mut u_ws = u0.clone();
        let stats_ws = b.run(|| {
            // restart U from u0 each sample (matching the allocating
            // arm's clone) so both rows measure identical numerical work
            // — only (V, S) warm-start across samples, in both arms
            u_ws.copy_from(&u0);
            NativeKernel
                .local_epoch(&mut u_ws, &prob.observed, &mut state_b, &hyper, 1.0, 1e-3, 2, &mut ws)
                .unwrap()
        });
        push(&mut t, &mut records, "local_epoch (workspace)", &shape, stats_ws.mean, None);

        let speedup = stats_alloc.mean / stats_ws.mean;
        println!("local epoch at {shape}: workspace path {speedup:.2}x vs allocating");
    }

    // SVD costs (what the baselines pay per iteration)
    {
        let a = Mat::gaussian(200, 200, &mut rng);
        let stats = b.run(|| svd_jacobi(&a));
        push(&mut t, &mut records, "svd_jacobi", "200x200", stats.mean, None);
        let big = Mat::gaussian(1000, 1000, &mut rng);
        let stats = b.run(|| rsvd(&big, RsvdParams::new(60)));
        push(&mut t, &mut records, "rsvd k=60", "1000x1000", stats.mean, None);
    }

    // transport framing round-trip
    {
        let u = Mat::gaussian(500, 25, &mut rng);
        let stats = b.run(|| {
            let msg = dcf_pca::coordinator::protocol::ToClient::Round {
                round: 0,
                k_local: 2,
                eta: 0.1,
                u: u.clone(),
            };
            let bytes = msg.encode();
            dcf_pca::coordinator::protocol::ToClient::decode(&bytes).unwrap()
        });
        let mbps = (500.0 * 25.0 * 8.0) / stats.mean / 1e6;
        t.row(&[
            "protocol enc+dec".into(),
            "U 500x25".into(),
            fmt_secs(stats.mean),
            format!("{mbps:.0} MB/s"),
        ]);
        records.push(Record {
            op: "protocol enc+dec".to_string(),
            shape: "U 500x25".to_string(),
            ns_per_iter: stats.mean * 1e9,
            gflops: None,
        });
    }

    // PJRT artifact execution (if built and the runtime is available)
    if std::path::Path::new("artifacts/manifest.json").exists() {
        match dcf_pca::runtime::PjrtKernel::load("artifacts") {
            Ok(kernel) => {
                let spec = ProblemSpec { m: 64, n: 32, rank: 4, sparsity: 0.05 };
                let p = spec.generate(9);
                let hyper = FactorHyper::default_for(64, 32, 4);
                let u0 = Mat::gaussian(64, 4, &mut rng);
                let mut state = ClientState::zeros(64, 32, 4);
                let mut ws = Workspace::new(64, 32, 4);
                let mut u = u0.clone();
                // warm compile
                kernel
                    .local_epoch(&mut u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2, &mut ws)
                    .unwrap();
                let stats = b.run(|| {
                    let mut u = u0.clone();
                    kernel
                        .local_epoch(&mut u, &p.observed, &mut state, &hyper, 0.5, 1e-3, 2, &mut ws)
                        .unwrap()
                });
                push(&mut t, &mut records, "pjrt client_update", "m=64 n_i=32 r=4 K=2", stats.mean, None);
            }
            Err(err) => println!("(PJRT unavailable — skipping artifact rows: {err})"),
        }
    } else {
        println!("(artifacts not built — skipping PJRT row; run `make artifacts`)");
    }

    println!("\nkernel hot-path timings:");
    t.print();

    let json = Json::Arr(records.iter().map(Record::to_json).collect());
    let out_path = "BENCH_kernel_hotpath.json";
    match std::fs::write(out_path, format!("{json}\n")) {
        Ok(()) => println!("\nmachine-readable results written to {out_path}"),
        Err(err) => eprintln!("could not write {out_path}: {err}"),
    }
}
