//! Bench: regenerate paper Fig. 4 — local-iteration ablation
//! K ∈ {1,2,5,10} at fixed η = 0.01, E = 10.

use dcf_pca::experiments::{fig4, Effort};

fn main() {
    let effort = Effort::from_env();
    println!("fig4 local-iterations bench (mode: {effort:?})");
    let series = fig4::run(effort);
    let k1 = series.iter().find(|s| s.k_local == 1).unwrap();
    let k10 = series.iter().find(|s| s.k_local == 10).unwrap();
    // paper: K=10 converges in far fewer rounds than K=1
    match (k10.rounds_to_recover, k1.rounds_to_recover) {
        (Some(fast), Some(slow)) => {
            assert!(fast < slow, "K=10 ({fast}) should beat K=1 ({slow})")
        }
        (Some(_), None) => {} // K=1 never reached threshold: even stronger
        other => panic!("K=10 should recover: {other:?}"),
    }
    // paper: larger K drifts more between synchronizations
    assert!(
        k10.mean_dispersion > k1.mean_dispersion,
        "dispersion should grow with K ({} vs {})",
        k10.mean_dispersion,
        k1.mean_dispersion
    );
    println!("fig4 OK");
}
