//! Bench: regenerate paper Fig. 3 (σ spectrum with p = 2r) and Table 1
//! (relative σ errors across scales; paper: .0286/.0326/.0398/.1127).

use dcf_pca::experiments::{fig3_table1, Effort};

fn main() {
    let effort = Effort::from_env();
    println!("fig3/table1 upper-bound-rank bench (mode: {effort:?})");
    let rows = fig3_table1::run(effort);
    for row in &rows {
        // same order of magnitude as the paper's column
        assert!(
            row.sv_error < 0.25,
            "n={}: σ error {} out of band (paper ~{:?})",
            row.n,
            row.sv_error,
            row.paper_value
        );
        // Fig. 3's claim: σ_{r+1}/σ_r is small (extra rank is silent)
        assert!(row.tail_ratio < 0.25, "n={}: tail ratio {}", row.n, row.tail_ratio);
    }
    println!("fig3/table1 OK");
}
