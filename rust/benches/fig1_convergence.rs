//! Bench: regenerate paper Fig. 1 — convergence + cost comparison of
//! DCF-PCA / CF-PCA / APGM / ALM across problem scales.
//!
//! `DCF_PCA_BENCH_MODE=full cargo bench --bench fig1_convergence` uses
//! the paper's n ∈ {500, 1000, 3000}; the default quick mode shrinks
//! scales (shape preserved). CSV series land in results/.

use dcf_pca::experiments::{fig1, Effort};

fn main() {
    let effort = Effort::from_env();
    println!("fig1 convergence bench (mode: {effort:?})");
    let rows = fig1::run(effort);
    // sanity assertions on the *shape* of the paper's claims
    for n in fig1::scales(effort) {
        let at = |alg: &str| rows.iter().find(|r| r.n == n && r.algorithm == alg).unwrap();
        let dcf = at("DCF-PCA");
        let cf = at("CF-PCA");
        let alm = at("ALM");
        assert!(dcf.final_err < 1e-2, "DCF-PCA recovers at n={n}");
        assert!(cf.final_err < 1e-2, "CF-PCA recovers at n={n}");
        assert!(alm.final_err < 1e-3, "ALM recovers at n={n}");
        // the paper's headline: distributed per-client cost < centralized
        assert!(
            dcf.critical_path_secs < cf.wall_secs,
            "n={n}: DCF per-client {} !< CF total {}",
            dcf.critical_path_secs,
            cf.wall_secs
        );
    }
    println!("fig1 OK");
}
