//! Bench: ablations on design choices (schedules, aggregation, wire
//! compression, partial participation, DP noise) + numerical checks of
//! Theorems 1 and 2.

use dcf_pca::experiments::{ablations, theory, Effort};

fn main() {
    let effort = Effort::from_env();
    println!("ablations bench (mode: {effort:?})");
    let rows = ablations::run(effort);

    // every schedule variant must recover
    for r in rows.iter().filter(|r| r.study == "schedule") {
        assert!(r.final_err < 1e-2, "{}: err {}", r.setting, r.final_err);
    }
    // compression: int8 cuts bytes ≥4× vs f64 and still recovers
    let none = rows.iter().find(|r| r.study == "compression" && r.setting == "None").unwrap();
    let int8 = rows.iter().find(|r| r.study == "compression" && r.setting == "Int8").unwrap();
    assert!(int8.bytes_per_round * 3.9 < none.bytes_per_round, "int8 should cut ≥ ~4x");
    assert!(int8.final_err < 1e-1, "int8 err {}", int8.final_err);
    // participation: sampled runs still recover (given proportionally
    // more rounds)
    for r in rows.iter().filter(|r| r.study == "participation") {
        assert!(r.final_err < 5e-2, "{}: err {}", r.setting, r.final_err);
    }
    // DP noise: zero-noise at least as good as the noisiest setting
    let dp0 = rows.iter().find(|r| r.study == "dp-noise" && r.setting.ends_with("0e0")).map(|r| r.final_err)
        .unwrap_or_else(|| rows.iter().find(|r| r.study == "dp-noise").unwrap().final_err);
    let dp_max = rows.iter().filter(|r| r.study == "dp-noise").map(|r| r.final_err).fold(0.0f64, f64::max);
    assert!(dp0 <= dp_max + 1e-12);

    let t1 = theory::run_theorem1(effort);
    for row in &t1 {
        // Theorem 1 bounds the RUNNING AVERAGE of ‖∇‖², with a K²η²
        // drift term — for small K the trajectory visibly decays; for
        // larger K the theorem only forbids growth. Check exactly that.
        if row.k_local <= 2 {
            assert!(
                row.mean_grad_sq_second_half < row.mean_grad_sq_first_half,
                "K={}: gradient norm should decay ({} !< {})",
                row.k_local,
                row.mean_grad_sq_second_half,
                row.mean_grad_sq_first_half
            );
        }
        assert!(
            row.mean_grad_sq_second_half < 2.0 * row.mean_grad_sq_first_half,
            "K={}: gradient norm must not diverge",
            row.k_local
        );
        assert!(row.final_err < 1e-2, "K={} recovers", row.k_local);
    }
    let t2 = theory::run_theorem2(effort);
    let good = t2.iter().find(|r| r.satisfies).unwrap();
    let bad = t2.iter().find(|r| !r.satisfies).unwrap();
    // compliant hyperparameters recover L (and overall err)
    assert!(good.final_err < 1e-2, "compliant run recovers: {}", good.final_err);
    assert!(good.l_only_err < 5e-2, "compliant run recovers L: {}", good.l_only_err);
    // violating ρ² > λ²mn: the over-regularized factorization cannot
    // represent L₀ — the L-component error stays ~O(1)
    assert!(
        bad.l_only_err > 0.5,
        "violating run must fail on L: {}",
        bad.l_only_err
    );
    println!("ablations OK");
}
