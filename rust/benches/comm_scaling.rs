//! Bench: §3.4 — measured communication per round vs Eq. 28 (2·E·m·r),
//! per-client compute vs E (Eq. 26), the coordinator's straggler cut,
//! and the hierarchical-aggregation tier: with relay RoundEngines
//! between the leaves and the root, the root's per-round ingest is
//! bounded by the tree's fan-in — it grows with the arity, not with E —
//! while the final factor stays bitwise identical to the flat star.
//! A codec section compares the wire codecs at fixed E=64 and gates the
//! bandwidth-roofline policy: top-k must cut ≥4× vs dense f64 with the
//! reveal error within 5e-2, and delta must stay bitwise lossless.
//!
//! The tree scenarios run in virtual time over the deterministic sim
//! (`TreeSim`), so the ingest bytes and the per-round latency
//! percentiles are exactly reproducible; the star scaling and straggler
//! sections measure real wall-clock over the in-process transport.
//!
//! Writes machine-readable results to `BENCH_comm_scaling.json` as
//! `{host, records}`: every record is `{op, shape, value, unit,
//! better}`, where `better` ("lower" | "higher") tells
//! `scripts/bench_trend.sh` which direction is a regression.

use std::collections::BTreeMap;
use std::time::Duration;

use dcf_pca::coordinator::Compression;
use dcf_pca::experiments::{comm, Effort};
use dcf_pca::linalg::simd;
use dcf_pca::sim::{FaultSchedule, TreeSim, TreeSimConfig};
use dcf_pca::util::json::Json;

/// One machine-readable bench record.
struct Record {
    op: String,
    shape: String,
    value: f64,
    unit: &'static str,
    /// which direction is an improvement — the trend script flags a
    /// regression when `value` moves the other way past tolerance
    better: &'static str,
}

impl Record {
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("op".to_string(), Json::Str(self.op.clone()));
        obj.insert("shape".to_string(), Json::Str(self.shape.clone()));
        obj.insert("value".to_string(), Json::Num(self.value));
        obj.insert("unit".to_string(), Json::Str(self.unit.to_string()));
        obj.insert("better".to_string(), Json::Str(self.better.to_string()));
        Json::Obj(obj)
    }
}

/// Host fingerprint for the JSON header (no perf probes here — the comm
/// numbers are bytes and virtual time, which don't depend on them).
fn host_header() -> Json {
    let features: Vec<Json> =
        simd::detected_features().into_iter().map(|f| Json::Str(f.to_string())).collect();
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut obj = BTreeMap::new();
    obj.insert("dispatch".to_string(), Json::Str(simd::Dispatch::active().name().to_string()));
    obj.insert("forced_scalar".to_string(), Json::Bool(simd::forced_scalar()));
    obj.insert("features".to_string(), Json::Arr(features));
    obj.insert("cores".to_string(), Json::Num(cores as f64));
    Json::Obj(obj)
}

fn push(
    records: &mut Vec<Record>,
    op: &str,
    shape: &str,
    value: f64,
    unit: &'static str,
    better: &'static str,
) {
    records.push(Record { op: op.to_string(), shape: shape.to_string(), value, unit, better });
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let idx = ((sorted.len() as f64 * q).ceil() as usize).clamp(1, sorted.len()) - 1;
    sorted[idx]
}

/// What one fault-free tree world measured at the root.
struct TreeRow {
    /// mean upstream bytes the root ingested per round
    ingest_mean: f64,
    fan_in_max: usize,
}

/// Run one fault-free tree federation in virtual time, assert the
/// fan-in/participation invariants, and emit its root-side records.
/// With `bitwise_vs_star` the same fleet also runs as a flat star and
/// the tree's final factor must match it bit for bit (`record_star`
/// additionally emits the star's ingest row for comparison).
fn run_tree_world(
    cfg: TreeSimConfig,
    bitwise_vs_star: bool,
    record_star: bool,
    records: &mut Vec<Record>,
) -> TreeRow {
    let (leaves, arity, rounds) = (cfg.leaves, cfg.arity, cfg.rounds);
    let sim = TreeSim::new(cfg).expect("tree sim config");
    let topo = *sim.topology();
    let schedule = FaultSchedule::fault_free(7, topo.top_count(), rounds);
    let out = sim.run_tree(&schedule).expect("fault-free tree run");
    assert_eq!(out.rounds.len(), rounds, "fault-free tree must complete every round");
    for r in &out.rounds {
        // every complete round folds exactly the top relay tier, and a
        // relay's count telemetry restores the full leaf participation
        assert_eq!(
            r.fan_in,
            topo.top_count(),
            "round {}: root fan-in {} with {} top-level relays",
            r.round,
            r.fan_in,
            topo.top_count()
        );
        assert_eq!(r.participants, leaves, "round {}: leaf participation", r.round);
    }
    let fan_in_max = out.rounds.iter().map(|r| r.fan_in).max().unwrap_or(0);
    assert!(fan_in_max <= arity, "root ingest must be bounded by the arity");
    let ingest_mean =
        out.rounds.iter().map(|r| r.bytes_up as f64).sum::<f64>() / rounds as f64;
    let mut secs: Vec<f64> = out.rounds.iter().map(|r| r.round_secs).collect();
    secs.sort_by(f64::total_cmp);
    let (p50_ms, p99_ms) = (1e3 * percentile(&secs, 0.5), 1e3 * percentile(&secs, 0.99));

    if bitwise_vs_star {
        let reference = sim.reference();
        assert!(
            out.u == reference.u,
            "tree U diverged bitwise from the star run (E={leaves}, arity={arity})"
        );
        if record_star {
            let star_ingest = reference.rounds.iter().map(|r| r.bytes_up as f64).sum::<f64>()
                / reference.rounds.len() as f64;
            push(
                records,
                "root_ingest_bytes_per_round",
                &format!("E={leaves} star"),
                star_ingest,
                "bytes",
                "lower",
            );
        }
    }

    let shape = format!("E={leaves} arity={arity}");
    println!(
        "tree {shape}: {} level(s), root fan-in {}, ingest {ingest_mean:.0} B/round, \
         virtual p50 {p50_ms:.1} ms p99 {p99_ms:.1} ms{}",
        topo.levels,
        topo.top_count(),
        if bitwise_vs_star { ", U bitwise == star" } else { "" }
    );
    push(records, "root_ingest_bytes_per_round", &shape, ingest_mean, "bytes", "lower");
    push(records, "root_fan_in_max", &shape, fan_in_max as f64, "updates", "lower");
    push(records, "round_p50_ms_virtual", &shape, p50_ms, "ms", "lower");
    push(records, "round_p99_ms_virtual", &shape, p99_ms, "ms", "lower");
    TreeRow { ingest_mean, fan_in_max }
}

fn main() {
    let effort = Effort::from_env();
    println!("comm/compute scaling bench (mode: {effort:?})");
    let mut records: Vec<Record> = Vec::new();

    let rows = comm::run(effort);
    for r in &rows {
        // Eq. 28: payload is exactly 2·E·m·r floats; framing (incl. the
        // 9-byte version/job/seq envelope) stays <5%
        assert!(
            r.overhead_frac < 0.05,
            "E={}: framing overhead {:.2}%",
            r.clients,
            100.0 * r.overhead_frac
        );
        let shape = format!("E={}", r.clients);
        let bpr = r.bytes_per_round;
        push(&mut records, "star_wire_bytes_per_round", &shape, bpr, "bytes", "lower");
        push(&mut records, "star_client_secs_per_round", &shape, r.client_secs, "s", "lower");
    }
    // per-client critical path falls as E grows (the paper's scalability
    // claim); allow slack for tiny-block constant costs
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(
        last.client_secs < first.client_secs,
        "per-client time should fall with E: E={} {}s vs E={} {}s",
        first.clients,
        first.client_secs,
        last.clients,
        last.client_secs
    );

    // straggler scenario: E=32, one client blows the per-round deadline
    // every round → the cut bounds latency at the deadline
    let s = comm::straggler_run(effort);
    println!(
        "straggler (E={}, {} slow by {:.0} ms, deadline {:.0} ms): \
         p50 {:.1} ms, p99 {:.1} ms (baseline p50 {:.1} ms), participants {}–{}",
        s.clients,
        s.slow_clients,
        1e3 * s.delay_secs,
        1e3 * s.deadline_secs,
        1e3 * s.round_p50_secs,
        1e3 * s.round_p99_secs,
        1e3 * s.baseline_p50_secs,
        s.participants_min,
        s.participants_max,
    );
    // structural invariants only — percentile *values* are reported, not
    // asserted tightly, so a loaded machine degrades numbers instead of
    // aborting the bench. The straggler always overshoots the deadline,
    // so it can never be counted as a participant…
    assert!(
        s.participants_max < s.clients,
        "straggler participated despite overshooting the deadline"
    );
    // …and the cut means no round ever waits out delay-after-deadline
    // sequentially; generous slack covers scheduler noise
    assert!(
        s.round_p50_secs < s.delay_secs + 2.0 * s.deadline_secs,
        "p50 {:.3}s looks like the straggler was waited for ({:.3}s delay)",
        s.round_p50_secs,
        s.delay_secs
    );
    let shape = format!("E={} slow={}", s.clients, s.slow_clients);
    push(&mut records, "straggler_round_p50", &shape, s.round_p50_secs, "s", "lower");
    push(&mut records, "straggler_round_p99", &shape, s.round_p99_secs, "s", "lower");

    // wire codecs at fixed E=64: the policy gate lives here as runtime
    // asserts against the *measured* dense baseline of the same run —
    // never against a hand-written byte count
    let codecs = comm::codec_run(effort);
    let dense = &codecs[0];
    assert_eq!(dense.codec, Compression::None, "codec_run leads with the dense baseline");
    for c in &codecs {
        let shape = format!("E={} codec={}", c.clients, c.codec.cli_name());
        push(&mut records, "codec_wire_bytes_per_round", &shape, c.bytes_per_round, "bytes", "lower");
        push(&mut records, "codec_compression_ratio", &shape, c.ratio, "x", "higher");
        push(&mut records, "codec_final_err", &shape, c.final_err, "err", "lower");
    }
    let delta = codecs.iter().find(|c| c.codec == Compression::Delta).expect("delta row");
    assert!(
        delta.bitwise_vs_dense,
        "a delta-coded run must reproduce the dense factor bit for bit"
    );
    let topk = codecs.iter().find(|c| c.codec == Compression::TopK).expect("topk row");
    assert!(
        dense.bytes_per_round >= 4.0 * topk.bytes_per_round,
        "top-k must cut wire bytes ≥4× vs dense f64: {:.0} B/round vs {:.0} B/round",
        dense.bytes_per_round,
        topk.bytes_per_round
    );
    assert!(
        topk.ratio >= 4.0,
        "the engine's compression meter disagrees with the ≥4× cut: {:.2}×",
        topk.ratio
    );
    assert!(
        (topk.final_err - dense.final_err).abs() <= 5e-2,
        "top-k reveal error drifted more than 5e-2 from dense: {:.3e} vs {:.3e}",
        topk.final_err,
        dense.final_err
    );

    // hierarchical aggregation: the root's ingest follows the tree's
    // fan-in. All tree worlds share the skinny per-leaf instance (m=8,
    // one column per leaf) so even the 10k-leaf federation is cheap.
    println!("\nhierarchical aggregation tier (virtual time):");
    let base = |leaves: usize, arity: usize, rounds: usize| TreeSimConfig {
        leaves,
        arity,
        m: 8,
        cols_per_leaf: 1,
        rank: 2,
        sparsity: 0.05,
        rounds,
        k_local: 1,
        problem_seed: 7,
        server_seed: 0xDCF,
        round_timeout: Duration::from_millis(50),
        threads: 0,
        mute: None,
        compression: Compression::None,
    };

    // arity sweep at fixed E=64: the top tier is exactly {2, 4, 8} wide,
    // so ingest must grow strictly with arity — and only with arity
    let sweep: Vec<TreeRow> = [2usize, 4, 8]
        .iter()
        .map(|&arity| run_tree_world(base(64, arity, 4), true, arity == 4, &mut records))
        .collect();
    assert!(
        sweep[0].ingest_mean < sweep[1].ingest_mean && sweep[1].ingest_mean < sweep[2].ingest_mean,
        "root ingest should grow with arity: {:?}",
        sweep.iter().map(|r| r.ingest_mean).collect::<Vec<_>>()
    );

    // E sweep at fixed arity 4: 64 and 1024 leaves both top out at a
    // 4-wide tier, so the root's ingest bytes must be *identical* —
    // coordinator load is set by the arity, not the federation size
    let big = run_tree_world(base(1024, 4, 4), true, true, &mut records);
    assert_eq!(
        sweep[1].ingest_mean, big.ingest_mean,
        "root ingest must not grow with E at fixed arity"
    );
    // while the equivalent star root ingests E updates per round
    let star_1024 = records
        .iter()
        .find(|r| r.op == "root_ingest_bytes_per_round" && r.shape == "E=1024 star")
        .expect("star baseline row")
        .value;
    assert!(
        star_1024 > 100.0 * big.ingest_mean,
        "a 1024-leaf star should ingest ≫ the 4-wide tree ({star_1024:.0} vs {:.0})",
        big.ingest_mean
    );

    // the headline scale point: a 10 000-leaf federation whose root
    // never serves more than the arity (3 top relays under arity 8)
    let huge = run_tree_world(base(10_000, 8, 2), false, false, &mut records);
    assert!(huge.fan_in_max <= 8);

    // machine-readable dump
    let mut top = BTreeMap::new();
    top.insert("host".to_string(), host_header());
    top.insert("records".to_string(), Json::Arr(records.iter().map(Record::to_json).collect()));
    let json = Json::Obj(top);
    let out_path = "BENCH_comm_scaling.json";
    match std::fs::write(out_path, format!("{json}\n")) {
        Ok(()) => println!("machine-readable results written to {out_path}"),
        Err(err) => eprintln!("could not write {out_path}: {err}"),
    }
    println!("comm OK");
}
