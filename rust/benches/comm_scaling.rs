//! Bench: §3.4 — measured communication per round vs Eq. 28 (2·E·m·r)
//! and per-client compute vs E (Eq. 26).

use dcf_pca::experiments::{comm, Effort};

fn main() {
    let effort = Effort::from_env();
    println!("comm/compute scaling bench (mode: {effort:?})");
    let rows = comm::run(effort);
    for r in &rows {
        // Eq. 28: payload is exactly 2·E·m·r floats; framing stays <5%
        assert!(
            r.overhead_frac < 0.05,
            "E={}: framing overhead {:.2}%",
            r.clients,
            100.0 * r.overhead_frac
        );
    }
    // per-client critical path falls as E grows (the paper's scalability
    // claim); allow slack for tiny-block constant costs
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(
        last.client_secs < first.client_secs,
        "per-client time should fall with E: E={} {}s vs E={} {}s",
        first.clients,
        first.client_secs,
        last.clients,
        last.client_secs
    );
    println!("comm OK");
}
