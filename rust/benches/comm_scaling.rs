//! Bench: §3.4 — measured communication per round vs Eq. 28 (2·E·m·r),
//! per-client compute vs E (Eq. 26), and the coordinator's straggler
//! cut: with E=32 and one client slower than the round deadline, round
//! latency pins to the deadline (max), never the straggler or the sum.
//!
//! Writes machine-readable results to `BENCH_comm_scaling.json`.

use std::collections::BTreeMap;

use dcf_pca::experiments::{comm, Effort};
use dcf_pca::util::json::Json;

fn num(v: f64) -> Json {
    Json::Num(v)
}

fn main() {
    let effort = Effort::from_env();
    println!("comm/compute scaling bench (mode: {effort:?})");
    let rows = comm::run(effort);
    for r in &rows {
        // Eq. 28: payload is exactly 2·E·m·r floats; framing (incl. the
        // 5-byte job envelope) stays <5%
        assert!(
            r.overhead_frac < 0.05,
            "E={}: framing overhead {:.2}%",
            r.clients,
            100.0 * r.overhead_frac
        );
    }
    // per-client critical path falls as E grows (the paper's scalability
    // claim); allow slack for tiny-block constant costs
    let first = rows.first().unwrap();
    let last = rows.last().unwrap();
    assert!(
        last.client_secs < first.client_secs,
        "per-client time should fall with E: E={} {}s vs E={} {}s",
        first.clients,
        first.client_secs,
        last.clients,
        last.client_secs
    );

    // straggler scenario: E=32, one client blows the per-round deadline
    // every round → the cut bounds latency at the deadline
    let s = comm::straggler_run(effort);
    println!(
        "straggler (E={}, {} slow by {:.0} ms, deadline {:.0} ms): \
         p50 {:.1} ms, p99 {:.1} ms (baseline p50 {:.1} ms), participants {}–{}",
        s.clients,
        s.slow_clients,
        1e3 * s.delay_secs,
        1e3 * s.deadline_secs,
        1e3 * s.round_p50_secs,
        1e3 * s.round_p99_secs,
        1e3 * s.baseline_p50_secs,
        s.participants_min,
        s.participants_max,
    );
    // structural invariants only — percentile *values* are reported, not
    // asserted tightly, so a loaded machine degrades numbers instead of
    // aborting the bench. The straggler always overshoots the deadline,
    // so it can never be counted as a participant…
    assert!(
        s.participants_max < s.clients,
        "straggler participated despite overshooting the deadline"
    );
    // …and the cut means no round ever waits out delay-after-deadline
    // sequentially; generous slack covers scheduler noise
    assert!(
        s.round_p50_secs < s.delay_secs + 2.0 * s.deadline_secs,
        "p50 {:.3}s looks like the straggler was waited for ({:.3}s delay)",
        s.round_p50_secs,
        s.delay_secs
    );

    // machine-readable dump
    let mut straggler = BTreeMap::new();
    straggler.insert("clients".to_string(), num(s.clients as f64));
    straggler.insert("slow_clients".to_string(), num(s.slow_clients as f64));
    straggler.insert("delay_secs".to_string(), num(s.delay_secs));
    straggler.insert("deadline_secs".to_string(), num(s.deadline_secs));
    straggler.insert("round_p50_secs".to_string(), num(s.round_p50_secs));
    straggler.insert("round_p99_secs".to_string(), num(s.round_p99_secs));
    straggler.insert("baseline_p50_secs".to_string(), num(s.baseline_p50_secs));
    straggler.insert("participants_min".to_string(), num(s.participants_min as f64));
    straggler.insert("participants_max".to_string(), num(s.participants_max as f64));

    let scaling = Json::Arr(
        rows.iter()
            .map(|r| {
                let mut o = BTreeMap::new();
                o.insert("clients".to_string(), num(r.clients as f64));
                o.insert("bytes_per_round".to_string(), num(r.bytes_per_round));
                o.insert("eq28_payload".to_string(), num(r.eq28_payload as f64));
                o.insert("overhead_frac".to_string(), num(r.overhead_frac));
                o.insert("client_secs".to_string(), num(r.client_secs));
                o.insert("total_secs".to_string(), num(r.total_secs));
                o.insert("final_err".to_string(), num(r.final_err));
                Json::Obj(o)
            })
            .collect(),
    );
    let mut root = BTreeMap::new();
    root.insert("scaling".to_string(), scaling);
    root.insert("straggler".to_string(), Json::Obj(straggler));
    let json = Json::Obj(root);
    let out_path = "BENCH_comm_scaling.json";
    match std::fs::write(out_path, format!("{json}\n")) {
        Ok(()) => println!("machine-readable results written to {out_path}"),
        Err(err) => eprintln!("could not write {out_path}: {err}"),
    }
    println!("comm OK");
}
