//! Bench: the out-of-core data layer (§Data of EXPERIMENTS.md).
//!
//! Two comparisons, written to `BENCH_data_io.json`:
//!
//! 1. **Load**: parsing a text CSV vs opening + materializing a
//!    `.dcfshard` (binary, panel-major, checksummed) of the same matrix
//!    — the format change is the first win (no float parsing, one
//!    sequential pass).
//! 2. **Epoch throughput**: a resident local epoch vs the identical
//!    epoch streamed from the shard panel by panel (positioned reads +
//!    page-cache readahead, the same fused pipeline). The gap between
//!    the two rows is the true cost of going out-of-core; gflops and
//!    `effective_gb_per_s` use the PR-2 fused traffic model.
//!
//! Like every bench here, each run overwrites the JSON snapshot — the
//! perf trajectory accumulates as the file's git history.

use std::collections::BTreeMap;

use dcf_pca::algorithms::factor::{ClientState, FactorHyper};
use dcf_pca::bench_util::{fmt_secs, Bencher, Table};
use dcf_pca::cli::commands::generate::{read_matrix_csv, write_matrix_csv};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::data::{shard::write_block, DataSource, ShardSource};
use dcf_pca::linalg::panel_width;
use dcf_pca::rng::Pcg64;
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::util::json::Json;
use dcf_pca::{Mat, Workspace};

struct Record {
    op: String,
    shape: String,
    ns_per_iter: f64,
    gflops: Option<f64>,
    effective_gb_per_s: Option<f64>,
}

impl Record {
    fn to_json(&self) -> Json {
        let mut obj = BTreeMap::new();
        obj.insert("op".to_string(), Json::Str(self.op.clone()));
        obj.insert("shape".to_string(), Json::Str(self.shape.clone()));
        obj.insert("ns_per_iter".to_string(), Json::Num(self.ns_per_iter));
        let opt = |v: Option<f64>| match v {
            Some(x) => Json::Num(x),
            None => Json::Null,
        };
        obj.insert("gflops".to_string(), opt(self.gflops));
        obj.insert("effective_gb_per_s".to_string(), opt(self.effective_gb_per_s));
        Json::Obj(obj)
    }
}

/// FLOPs of one local epoch (same model as `benches/kernel_hotpath.rs`).
fn epoch_flops(m: usize, n: usize, p: usize, j: usize, k: usize) -> f64 {
    let mnp = (m * n * p) as f64;
    (k * j) as f64 * 4.0 * mnp + k as f64 * 4.0 * mnp
}

/// Fused-epoch traffic model (same as `benches/kernel_hotpath.rs`).
fn fused_epoch_bytes(m: usize, n: usize, j: usize, k: usize) -> f64 {
    let mn = (m * n) as f64 * 8.0;
    (k * j) as f64 * 3.0 * mn + k as f64 * 2.0 * mn
}

fn main() {
    let mut rng = Pcg64::new(3);
    let b = Bencher { warmup: 1, samples: 5, max_total: std::time::Duration::from_secs(180) };
    let mut t = Table::new(&["op", "shape", "time (mean)", "GFLOP/s", "eff GB/s"]);
    let mut records: Vec<Record> = Vec::new();

    let push = |t: &mut Table,
                records: &mut Vec<Record>,
                op: &str,
                shape: &str,
                mean: f64,
                gflops: Option<f64>,
                gbs: Option<f64>| {
        let fmt_opt = |v: Option<f64>| v.map(|g| format!("{g:.2}")).unwrap_or_else(|| "—".into());
        t.row(&[op.into(), shape.into(), fmt_secs(mean), fmt_opt(gflops), fmt_opt(gbs)]);
        records.push(Record {
            op: op.to_string(),
            shape: shape.to_string(),
            ns_per_iter: mean * 1e9,
            gflops,
            effective_gb_per_s: gbs,
        });
    };

    let dir = std::env::temp_dir().join(format!("dcf-data-io-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();

    // --- load-path comparison: CSV parse vs shard open+materialize ---
    {
        let (m, n) = (1000usize, 1000usize);
        let mat = Mat::gaussian(m, n, &mut rng);
        let shape = format!("{m}x{n}");
        let csv_path = dir.join("load.csv");
        let shard_path = dir.join("load.dcfshard");
        write_matrix_csv(csv_path.to_str().unwrap(), &mat).unwrap();
        write_block(&shard_path, &mat, panel_width(m, n), 0, n, 3).unwrap();
        let mb = (m * n * 8) as f64;

        let stats = b.run(|| read_matrix_csv(csv_path.to_str().unwrap()).unwrap());
        let gbs = Some(mb / stats.mean / 1e9);
        push(&mut t, &mut records, "load csv", &shape, stats.mean, None, gbs);

        let stats = b.run(|| ShardSource::open(&shard_path).unwrap().to_mat().unwrap());
        push(
            &mut t,
            &mut records,
            "load shard",
            &shape,
            stats.mean,
            None,
            Some(mb / stats.mean / 1e9),
        );
    }

    // --- epoch throughput: resident vs streamed, same bits ---
    let (j_sweeps, k_local) = (3usize, 2usize);
    for &p_width in &[5usize, 25] {
        let (m, n) = (1000usize, 1000usize);
        let spec = ProblemSpec { m, n, rank: p_width, sparsity: 0.05 };
        let prob = spec.generate(13);
        let hyper = FactorHyper::default_for(m, n, p_width);
        assert_eq!(hyper.inner_sweeps, j_sweeps, "flop/byte models assume J = inner_sweeps");
        let u0 = Mat::gaussian(m, p_width, &mut rng);
        let shape = format!("m=n={m} p={p_width} J={j_sweeps} K={k_local}");
        let flops = epoch_flops(m, n, p_width, j_sweeps, k_local);
        let bytes = fused_epoch_bytes(m, n, j_sweeps, k_local);

        let shard_path = dir.join(format!("epoch-p{p_width}.dcfshard"));
        write_block(&shard_path, &prob.observed, panel_width(m, n), 0, n, 13).unwrap();
        let shard = ShardSource::open(&shard_path).unwrap();

        let kernel = NativeKernel::with_threads(2);
        let mut outputs: Vec<Mat> = Vec::new();
        for (label, src) in
            [("resident", &prob.observed as &dyn DataSource), ("streamed", &shard)]
        {
            let mut state = ClientState::zeros(m, n, p_width);
            let mut ws = Workspace::for_source(src, p_width);
            let mut u = u0.clone();
            let stats = b.run(|| {
                u.copy_from(&u0);
                kernel
                    .local_epoch(&mut u, src, &mut state, &hyper, 1.0, 1e-3, k_local, &mut ws)
                    .unwrap()
            });
            push(
                &mut t,
                &mut records,
                &format!("local_epoch ({label} t2)"),
                &shape,
                stats.mean,
                Some(flops / stats.mean / 1e9),
                Some(bytes / stats.mean / 1e9),
            );
            outputs.push(u);
        }
        assert_eq!(outputs[0], outputs[1], "streamed epoch diverged from resident (p={p_width})");
    }

    println!("\ndata I/O timings:");
    t.print();

    let json = Json::Arr(records.iter().map(Record::to_json).collect());
    let out_path = "BENCH_data_io.json";
    match std::fs::write(out_path, format!("{json}\n")) {
        Ok(()) => println!("\nmachine-readable results written to {out_path}"),
        Err(err) => eprintln!("could not write {out_path}: {err}"),
    }
    std::fs::remove_dir_all(&dir).ok();
}
