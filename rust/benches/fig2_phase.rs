//! Bench: regenerate paper Fig. 2 — the (sparsity × rank) recovery phase
//! diagram at m = n = 500 (quick mode: 200).

use dcf_pca::experiments::{fig2, Effort};

fn main() {
    let effort = Effort::from_env();
    println!("fig2 phase-diagram bench (mode: {effort:?})");
    let cells = fig2::run(effort);
    // shape checks: the easy corner recovers, the hard corner does not
    let easy = cells
        .iter()
        .find(|c| c.sparsity <= 0.051 && c.rank_frac <= 0.051)
        .expect("easy cell present");
    assert!(easy.recovered, "easy corner must recover (err {})", easy.err);
    let hard = cells
        .iter()
        .filter(|c| c.sparsity >= 0.24 && c.rank_frac >= 0.19)
        .collect::<Vec<_>>();
    if !hard.is_empty() {
        assert!(
            hard.iter().all(|c| !c.recovered),
            "hard corner should fail (paper limit r≈0.15n, s≈0.2)"
        );
    }
    println!("fig2 OK");
}
