//! Integration tests for the hierarchical aggregation tier (PR 8).
//!
//! The engine's reduction associates over aligned power-of-two slot
//! spans, so grouping any leaf fleet under relay RoundEngines must not
//! change a single bit of the result: every test here runs the same
//! fleet as a flat star and as a tree and compares the final factor
//! bitwise. The exception is the 10 000-leaf test — the whole point of
//! the tier is that the root only ever serves `top_count() ≤ arity`
//! connections, so that world asserts the fan-in bound and never pays
//! for the star baseline.

use dcf_pca::sim::{Fault, FaultSchedule, TreeSim, TreeSimConfig};

fn tree_sim(cfg: TreeSimConfig) -> TreeSim {
    TreeSim::new(cfg).expect("tree sim config must validate")
}

/// A latency-jitter-only schedule sized for the root's relay tier.
fn calm_tree_schedule(sim: &TreeSim, seed: u64) -> FaultSchedule {
    FaultSchedule::fault_free(seed, sim.topology().top_count(), sim.config().rounds)
}

#[test]
fn tree_reduction_is_bitwise_identical_to_star_across_arities() {
    for arity in [2usize, 4, 8] {
        let sim = tree_sim(TreeSimConfig { arity, ..TreeSimConfig::default() });
        let top = sim.topology().top_count();
        // different schedule seeds draw different per-message latency
        // jitter, so partials reach every relay in different orders —
        // the canonical span reduction must not care
        for schedule_seed in [1u64, 42, 1337] {
            let out = sim
                .run_tree(&calm_tree_schedule(&sim, schedule_seed))
                .expect("fault-free tree run must complete");
            let reference = sim.reference();
            assert_eq!(
                out.u,
                reference.u,
                "arity {arity}, schedule seed {schedule_seed}: tree U diverged from star"
            );
            assert_eq!(out.rounds.len(), reference.rounds.len());
            for (a, b) in out.rounds.iter().zip(&reference.rounds) {
                assert_eq!(a.err, b.err, "arity {arity} round {}: err diverged", a.round);
                assert_eq!(
                    a.mean_grad_norm,
                    b.mean_grad_norm,
                    "arity {arity} round {}: gradient telemetry diverged",
                    a.round
                );
                assert_eq!(a.fan_in, top, "root must ingest exactly the top relay tier");
                assert_eq!(a.participants, sim.config().leaves);
            }
        }
    }
}

#[test]
fn thread_pool_width_never_changes_the_factor() {
    let mut factors = Vec::new();
    for threads in [1usize, 2, 4] {
        let sim = tree_sim(TreeSimConfig { threads, ..TreeSimConfig::default() });
        let out = sim
            .run_tree(&calm_tree_schedule(&sim, 5))
            .expect("fault-free tree run must complete");
        assert_eq!(out.u, sim.reference().u, "threads {threads}: tree diverged from star");
        factors.push(out.u);
    }
    assert!(
        factors.windows(2).all(|w| w[0] == w[1]),
        "final factor depends on the kernel lane count"
    );
}

#[test]
fn cut_leaf_round_stays_bitwise_equal_to_star() {
    // leaf 5's reply to round 2 is swallowed in BOTH worlds (the mute
    // wrapper rides inside the shared leaf fleet), so the relay's
    // subtree cut must resolve to exactly the skip the star coordinator
    // applies: same slot set aggregated, same factor, one leaf-round of
    // participation gone in each
    let sim = tree_sim(TreeSimConfig { mute: Some((5, 2)), ..TreeSimConfig::default() });
    let out = sim
        .run_tree(&calm_tree_schedule(&sim, 9))
        .expect("tree run with one muted leaf must complete");
    let reference = sim.reference();
    assert_eq!(out.u, reference.u, "cut-leaf tree U diverged from the cut-leaf star");
    for (a, b) in out.rounds.iter().zip(&reference.rounds) {
        assert_eq!(a.err, b.err, "round {}: err diverged", a.round);
        let expected = if a.round == 2 { sim.config().leaves - 1 } else { sim.config().leaves };
        assert_eq!(a.participants, expected, "round {}", a.round);
        assert_eq!(b.participants, expected, "star round {}", b.round);
    }
}

#[test]
fn ten_thousand_leaves_arity_eight_keeps_root_fan_in_bounded() {
    let sim = tree_sim(TreeSimConfig {
        leaves: 10_000,
        arity: 8,
        cols_per_leaf: 1,
        rounds: 2,
        k_local: 1,
        ..TreeSimConfig::default()
    });
    let topo = *sim.topology();
    assert_eq!((topo.levels, topo.top_span(), topo.top_count()), (4, 4096, 3));
    // never touch sim.reference() here: the 10k-leaf star baseline is
    // exactly the world the tier exists to avoid, and the lazy
    // reference cell means we never pay for it
    let out = sim
        .run_tree(&FaultSchedule::fault_free(7, topo.top_count(), 2))
        .expect("10k-leaf tree run must complete");
    assert_eq!(out.rounds.len(), 2);
    for r in &out.rounds {
        assert!(
            r.fan_in <= topo.arity,
            "round {}: root ingested {} partials with arity {}",
            r.round,
            r.fan_in,
            topo.arity
        );
        assert_eq!(r.fan_in, topo.top_count());
        assert_eq!(r.participants, 10_000, "round {}: a subtree went missing", r.round);
    }
}

#[test]
fn recoverable_relay_flap_is_bitwise_invisible() {
    let sim = tree_sim(TreeSimConfig::default());
    let mut schedule = calm_tree_schedule(&sim, 0);
    // relay 1 drops its upstream link mid-run and redials 5 ms later —
    // inside the resume budget, so its session token must splice the
    // whole subtree back in with nothing cut
    schedule.faults.push(Fault::Disconnect { client: 1, at_ms: 20, reconnect_after_ms: 5 });
    assert!(
        schedule.under_budget(sim.config().round_timeout),
        "test premise broken: this flap should be inside the resume budget"
    );
    let report = sim.check_tree_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok);
    assert!(report.bitwise_clean, "a recoverable relay flap left a trace in the reduction");
}

#[test]
fn long_relay_outage_degrades_to_a_subtree_cut() {
    let sim = tree_sim(TreeSimConfig::default());
    let mut schedule = calm_tree_schedule(&sim, 0);
    // the outage outlives the round deadline: the relay departs and its
    // subtree is skipped, but the remaining relays carry the job
    schedule.faults.push(Fault::Disconnect { client: 1, at_ms: 20, reconnect_after_ms: 200 });
    assert!(!schedule.under_budget(sim.config().round_timeout));
    let report = sim.check_tree_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok, "three healthy relays must carry the job to completion");
    assert!(!report.bitwise_clean, "an over-budget outage cannot be bitwise clean");
}

#[test]
fn relay_crash_takes_its_subtree_as_one_straggler() {
    let sim = tree_sim(TreeSimConfig::default());
    let mut schedule = calm_tree_schedule(&sim, 0);
    // killing one relay removes its whole 4-leaf subtree at once; the
    // root must treat that as a single straggler cut, not an abort
    schedule.faults.push(Fault::CrashAt { client: 2, at_ms: 10 });
    let report = sim.check_tree_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok, "a relay crash must degrade, not abort");
    assert!(!report.bitwise_clean);
    let span = sim.topology().top_span();
    assert!(
        report.min_participants <= sim.config().leaves - span,
        "no round lost the crashed relay's {span}-leaf subtree (min participants {})",
        report.min_participants
    );
}

#[test]
fn tree_fuzz_sweep_holds_across_drawn_schedules() {
    let sim = tree_sim(TreeSimConfig::default());
    let summary = sim.fuzz_tree(0..32);
    assert_eq!(summary.seeds_run, 32);
    for v in &summary.failures {
        eprintln!("{v}");
    }
    assert!(
        summary.failures.is_empty(),
        "{} tree worlds violated invariants (replay lines above)",
        summary.failures.len()
    );
    // the sweep must actually exercise the fault space and the bitwise
    // check, not just terminate
    assert!(summary.reports.iter().any(|r| r.faults > 0), "sweep never drew a relay fault");
    assert!(summary.reports.iter().any(|r| r.bitwise_clean), "sweep never verified a calm world");
    // a passing schedule has nothing to shrink
    assert!(sim.shrink_tree(&calm_tree_schedule(&sim, 3)).is_none());
}
