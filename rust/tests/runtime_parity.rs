//! PJRT-artifact vs native-kernel parity, and the full coordinator loop
//! through the artifact path. Requires `make artifacts`; tests
//! self-skip (with a loud message) if the manifest is absent so plain
//! `cargo test` stays runnable before the artifacts are built.

use std::sync::Arc;

use dcf_pca::algorithms::factor::{ClientState, FactorHyper};
use dcf_pca::algorithms::Schedule;
use dcf_pca::coordinator::driver::{run_dcf_pca, DcfPcaConfig, KernelSpec};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::linalg::{Mat, Workspace};
use dcf_pca::rng::Pcg64;
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::runtime::{Manifest, PjrtKernel};

/// Self-skip helper: parity tests need both the AOT artifacts on disk
/// AND a working PJRT runtime (the `xla`-less stub build makes
/// `PjrtKernel::load` fail even when artifacts exist).
fn load_kernel_or_skip() -> Option<PjrtKernel> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts/manifest.json missing — run `make artifacts`");
        return None;
    }
    match PjrtKernel::load("artifacts") {
        Ok(kernel) => Some(kernel),
        Err(err) => {
            eprintln!("SKIP: PJRT runtime unavailable: {err:#}");
            None
        }
    }
}

#[test]
fn every_manifest_variant_matches_native() {
    let kernel = match load_kernel_or_skip() {
        Some(k) => k,
        None => return,
    };
    let manifest = Manifest::load("artifacts").unwrap();
    for v in &manifest.variants {
        let rel = dcf_pca::cli::commands::artifacts_check::check_variant(
            &kernel,
            v.m,
            v.n_i,
            v.r,
            v.k_local,
            v.inner_sweeps,
        )
        .unwrap();
        assert!(rel < 2e-3, "variant {} parity {rel}", v.file);
    }
}

#[test]
fn padded_narrow_block_matches_native() {
    // variant client_m64_n32_r4 exists; feed a 17-column block (padded
    // to 32 inside the executor) and compare against native on the
    // unpadded block.
    let kernel = match load_kernel_or_skip() {
        Some(k) => k,
        None => return,
    };
    let spec = ProblemSpec { m: 64, n: 17, rank: 4, sparsity: 0.05 };
    let problem = spec.generate(21);
    let mut hyper = FactorHyper::default_for(64, 17, 4);
    hyper.inner_sweeps = 3;
    let mut rng = Pcg64::new(3);
    let u = Mat::gaussian(64, 4, &mut rng);

    let mut ws = Workspace::new(64, 17, 4);
    let mut st_native = ClientState::zeros(64, 17, 4);
    let mut u_native = u.clone();
    NativeKernel::new()
        .local_epoch(&mut u_native, &problem.observed, &mut st_native, &hyper, 0.3, 1e-3, 2, &mut ws)
        .unwrap();
    let mut st_pjrt = ClientState::zeros(64, 17, 4);
    let mut u_pjrt = u.clone();
    kernel
        .local_epoch(&mut u_pjrt, &problem.observed, &mut st_pjrt, &hyper, 0.3, 1e-3, 2, &mut ws)
        .unwrap();

    assert_eq!(st_pjrt.v.shape(), (17, 4));
    assert_eq!(st_pjrt.s.shape(), (64, 17));
    let rel = |a: &Mat, b: &Mat| (a - b).frob_norm() / b.frob_norm().max(1e-12);
    assert!(rel(&u_pjrt, &u_native) < 2e-3);
    assert!(rel(&st_pjrt.v, &st_native.v) < 2e-3);
    assert!(rel(&st_pjrt.s, &st_native.s) < 2e-3);
}

#[test]
fn full_coordinator_loop_through_pjrt() {
    let kernel = match load_kernel_or_skip() {
        Some(k) => k,
        None => return,
    };
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(42);
    let mut cfg = DcfPcaConfig::default_for(&spec)
        .with_clients(5)
        .with_rounds(25)
        .with_k_local(2)
        .with_schedule(Schedule::Const { eta: 2e-2 });
    cfg.kernel = KernelSpec::Custom(Arc::new(kernel));
    let res = run_dcf_pca(&problem, &cfg).unwrap();
    assert!(
        res.final_error.unwrap() < 5e-2,
        "PJRT coordinator run err {:?}",
        res.final_error
    );
}

#[test]
fn missing_variant_is_a_clean_error() {
    let kernel = match load_kernel_or_skip() {
        Some(k) => k,
        None => return,
    };
    let spec = ProblemSpec { m: 123, n: 10, rank: 7, sparsity: 0.05 };
    let problem = spec.generate(1);
    let hyper = FactorHyper::default_for(123, 10, 7);
    let mut st = ClientState::zeros(123, 10, 7);
    let mut ws = Workspace::new(123, 10, 7);
    let mut rng = Pcg64::new(1);
    let mut u = Mat::gaussian(123, 7, &mut rng);
    let err = kernel
        .local_epoch(&mut u, &problem.observed, &mut st, &hyper, 1.0, 1e-3, 2, &mut ws)
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("no artifact variant"), "got: {msg}");
    assert!(msg.contains("make artifacts"), "got: {msg}");
}

#[test]
fn mismatched_hyper_is_a_clean_error() {
    let kernel = match load_kernel_or_skip() {
        Some(k) => k,
        None => return,
    };
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(2);
    let mut hyper = FactorHyper::default_for(40, 40, 2);
    hyper.lambda *= 3.0; // not what the artifacts were baked with
    let mut st = ClientState::zeros(40, 40, 2);
    let mut ws = Workspace::new(40, 40, 2);
    let mut rng = Pcg64::new(2);
    let mut u = Mat::gaussian(40, 2, &mut rng);
    let err = kernel
        .local_epoch(&mut u, &problem.observed, &mut st, &hyper, 1.0, 1e-3, 1, &mut ws)
        .unwrap_err();
    assert!(format!("{err:#}").contains("re-run `make artifacts`"));
}
