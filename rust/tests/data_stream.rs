//! Out-of-core streaming parity: epochs fed from `.dcfshard` files must
//! be *bitwise* identical to epochs fed from the resident matrix — at
//! every thread count, through every layer that touches panels.

use std::path::PathBuf;

use dcf_pca::algorithms::factor::{ClientState, FactorHyper};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::data::{write_shards, DataSource, MatrixSource, ShardManifest, ShardSource};
use dcf_pca::linalg::{panel_count, panel_width};
use dcf_pca::rng::Pcg64;
use dcf_pca::rpca::partition::ColumnPartition;
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::{Mat, Workspace};

fn tmpdir() -> PathBuf {
    let dir = std::env::temp_dir().join(format!("dcf-stream-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// One full local epoch from `src`, at a private pool of `threads`.
fn epoch(src: &dyn DataSource, threads: usize, p: usize, seed: u64) -> (Mat, Mat, Mat, u64) {
    let (m, n) = (src.rows(), src.cols());
    let hyper = FactorHyper::default_for(m, n, p);
    let mut rng = Pcg64::new(seed);
    let mut u = Mat::gaussian(m, p, &mut rng);
    let mut state = ClientState::zeros(m, n, p);
    let mut ws = Workspace::for_source(src, p);
    let kernel = NativeKernel::with_threads(threads);
    let out = kernel
        .local_epoch(&mut u, src, &mut state, &hyper, 0.7, 1e-3, 3, &mut ws)
        .unwrap();
    (u, state.v, state.s, out.grad_norm.to_bits())
}

#[test]
fn streamed_epoch_bitwise_matches_resident_across_threads() {
    // multi-panel shape (panel_width(256, ·) = 64 → 5 panels) so the
    // slot dispatch genuinely interleaves streamed fetches
    let (m, n, p) = (256usize, 300usize, 4usize);
    assert!(panel_count(n, panel_width(m, n)) >= 4);
    let prob = ProblemSpec { m, n, rank: p, sparsity: 0.05 }.generate(21);

    let path = tmpdir().join("parity.dcfshard");
    let w = panel_width(m, n);
    dcf_pca::data::shard::write_block(&path, &prob.observed, w, 0, n, 21).unwrap();
    let shard = ShardSource::open(&path).unwrap();

    let reference = epoch(&prob.observed, 1, p, 10);
    for threads in [1usize, 2, 4] {
        let resident = epoch(&prob.observed, threads, p, 10);
        let streamed = epoch(&shard, threads, p, 10);
        assert_eq!(resident, reference, "resident t{threads} diverged from t1");
        assert_eq!(streamed, reference, "streamed t{threads} diverged from resident t1");
    }
    std::fs::remove_file(&path).ok();
}

#[test]
fn streamed_parity_holds_at_nondefault_panel_widths() {
    // a shard written at an explicit width must match a resident source
    // forced to the same width — the decomposition, not the storage,
    // decides the bits
    let (m, n, p) = (64usize, 45usize, 3usize);
    let prob = ProblemSpec { m, n, rank: p, sparsity: 0.05 }.generate(22);
    for w in [1usize, 7, 45, 64] {
        let path = tmpdir().join(format!("width{w}.dcfshard"));
        dcf_pca::data::shard::write_block(&path, &prob.observed, w, 0, n, 22).unwrap();
        let shard = ShardSource::open(&path).unwrap();
        let resident = MatrixSource::with_panel_width(prob.observed.clone(), w);
        assert_eq!(epoch(&shard, 2, p, 11), epoch(&resident, 2, p, 11), "width {w} diverged");
        std::fs::remove_file(&path).ok();
    }
}

#[test]
fn manifest_shards_reassemble_and_stream_per_client() {
    // end-to-end over the manifest: each client's shard, opened
    // independently, streams an epoch bitwise equal to the resident
    // block the partition would have handed that client
    let (m, n, p) = (40usize, 37usize, 2usize);
    let prob = ProblemSpec { m, n, rank: p, sparsity: 0.05 }.generate(23);
    let partition = ColumnPartition::even(n, 3);
    let prefix = tmpdir().join("fed");
    write_shards(&prob.observed, &partition, &prefix, 23, Some((p, 0.05))).unwrap();
    let manifest = ShardManifest::load(&prefix.with_file_name("fed.manifest.json")).unwrap();
    assert_eq!(manifest.partition().unwrap(), partition);

    for (i, entry) in manifest.shards.iter().enumerate() {
        let shard = ShardSource::open(std::path::Path::new(&entry.path)).unwrap();
        let (a, b) = partition.range(i);
        assert_eq!(shard.header().col_offset, a);
        let block = prob.observed.cols_range(a, b);
        assert_eq!(
            epoch(&shard, 2, p, 12),
            epoch(&block, 2, p, 12),
            "client {i} streamed epoch diverged"
        );
    }
}
