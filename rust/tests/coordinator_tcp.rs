//! Distributed integration over real TCP sockets: the server and clients
//! exercise the same binary protocol `dcf-pca serve`/`worker` use.

use std::time::Duration;

use dcf_pca::algorithms::factor::FactorHyper;
use dcf_pca::coordinator::client::{run_client, ClientConfig, FaultPlan};
use dcf_pca::coordinator::kernel::NativeKernel;
use dcf_pca::coordinator::protocol::{round_wire_size, update_wire_size};
use dcf_pca::coordinator::server::{run_server, FaultPolicy, ServerConfig};
use dcf_pca::coordinator::transport::tcp::{TcpAcceptor, TcpChannel};
use dcf_pca::coordinator::transport::Channel;
use dcf_pca::coordinator::PrivacySpec;
use dcf_pca::rpca::partition::ColumnPartition;
use dcf_pca::rpca::problem::ProblemSpec;

fn spawn_tcp_clients(
    addr: &str,
    problem: &dcf_pca::rpca::problem::RpcaProblem,
    partition: &ColumnPartition,
    faults: Vec<FaultPlan>,
) -> Vec<std::thread::JoinHandle<dcf_pca::anyhow::Result<u64>>> {
    let spec = problem.spec;
    (0..partition.num_clients())
        .map(|id| {
            let addr = addr.to_string();
            let (a, b) = partition.range(id);
            let m_block = problem.observed.cols_range(a, b);
            let truth = (problem.l0.cols_range(a, b), problem.s0.cols_range(a, b));
            let fault = faults.get(id).copied().unwrap_or_default();
            std::thread::spawn(move || -> dcf_pca::anyhow::Result<u64> {
                let mut ch = TcpChannel::connect(&addr)?;
                let cfg = ClientConfig {
                    id,
                    job: 0,
                    n_frac: (b - a) as f64 / spec.n as f64,
                    data: Box::new(m_block),
                    hyper: FactorHyper::default_for(spec.m, spec.n, spec.rank),
                    polish_sweeps: 3,
                    truth: Some(truth),
                    faults: fault,
                    compression: dcf_pca::coordinator::Compression::None,
                    dp_sigma: 0.0,
                };
                let _ = run_client(&mut ch, cfg, &NativeKernel::new());
                Ok(ch.bytes_sent())
            })
        })
        .collect()
}

#[test]
fn tcp_end_to_end_recovers_and_meters_bytes() {
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(11);
    let e = 4;
    let rounds = 30;
    let partition = ColumnPartition::even(spec.n, e);

    let acceptor = TcpAcceptor::bind("127.0.0.1:0").unwrap();
    let addr = acceptor.local_addr().unwrap();
    let handles = spawn_tcp_clients(&addr, &problem, &partition, vec![]);

    let mut channels: Vec<Box<dyn Channel>> = acceptor
        .accept_n(e)
        .unwrap()
        .into_iter()
        .map(|c| Box::new(c) as Box<dyn Channel>)
        .collect();
    let mut cfg = ServerConfig::new(spec.m, spec.rank, rounds, 2);
    cfg.err_denominator = Some(problem.l0.frob_norm_sq() + problem.s0.frob_norm_sq());
    let outcome = run_server(&mut channels, &cfg).unwrap();

    // recovery happened
    let last_err = outcome.rounds.last().unwrap().err.unwrap();
    assert!(last_err < 5e-3, "err {last_err}");
    assert_eq!(outcome.revealed.len(), e);

    // Eq. 28 accounting holds on real sockets too
    let per_round = (e * round_wire_size(spec.m, spec.rank)
        + e * update_wire_size(spec.m, spec.rank)) as u64;
    for r in &outcome.rounds {
        assert_eq!(r.bytes_down + r.bytes_up, per_round, "round {}", r.round);
    }

    for h in handles {
        h.join().unwrap().unwrap();
    }
}

#[test]
fn tcp_client_crash_with_skip_policy() {
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(12);
    let e = 3;
    let partition = ColumnPartition::even(spec.n, e);

    let acceptor = TcpAcceptor::bind("127.0.0.1:0").unwrap();
    let addr = acceptor.local_addr().unwrap();
    let faults = vec![
        FaultPlan::default(),
        FaultPlan { crash_at_round: Some(4), ..Default::default() },
        FaultPlan::default(),
    ];
    let handles = spawn_tcp_clients(&addr, &problem, &partition, faults);

    let mut channels: Vec<Box<dyn Channel>> = acceptor
        .accept_n(e)
        .unwrap()
        .into_iter()
        .map(|c| Box::new(c) as Box<dyn Channel>)
        .collect();
    let mut cfg = ServerConfig::new(spec.m, spec.rank, 20, 2);
    cfg.fault_policy = FaultPolicy::SkipMissing;
    cfg.round_timeout = Duration::from_secs(2);
    cfg.err_denominator = Some(problem.l0.frob_norm_sq() + problem.s0.frob_norm_sq());
    let outcome = run_server(&mut channels, &cfg).unwrap();

    assert!(outcome.withheld.contains(&1));
    assert_eq!(outcome.revealed.len(), 2);
    assert!(outcome.rounds.iter().any(|r| r.participants == 2));
    // survivors still make progress
    let last_err = outcome.rounds.last().unwrap().err;
    assert!(last_err.is_none() || last_err.unwrap() < 0.5);

    for h in handles {
        let _ = h.join().unwrap();
    }
}

#[test]
fn tcp_privacy_upload_independent_of_block_size() {
    // one client holds 4 columns, another 36 — their uploads must be
    // identical (m×r updates only), which is the §2.2 privacy argument
    // in its quantitative form.
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(13);
    let partition = ColumnPartition::from_sizes(&[4, 36]);
    let rounds = 10;

    let acceptor = TcpAcceptor::bind("127.0.0.1:0").unwrap();
    let addr = acceptor.local_addr().unwrap();
    let handles = spawn_tcp_clients(&addr, &problem, &partition, vec![]);

    let mut channels: Vec<Box<dyn Channel>> = acceptor
        .accept_n(2)
        .unwrap()
        .into_iter()
        .map(|c| Box::new(c) as Box<dyn Channel>)
        .collect();
    let mut cfg = ServerConfig::new(spec.m, spec.rank, rounds, 2);
    cfg.privacy = PrivacySpec::with_private([0usize, 1]); // both private
    let outcome = run_server(&mut channels, &cfg).unwrap();
    assert_eq!(outcome.revealed.len(), 0);
    assert_eq!(outcome.withheld, vec![0, 1]);

    let uploads: Vec<u64> = handles.into_iter().map(|h| h.join().unwrap().unwrap()).collect();
    assert_eq!(
        uploads[0], uploads[1],
        "uploads must not depend on n_i: {uploads:?}"
    );
    // and each upload is ≪ the larger block
    assert!(uploads[1] < (spec.m * 36 * 8) as u64);
}
