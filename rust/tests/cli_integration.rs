//! CLI integration: spawn the real `dcf-pca` binary and check the
//! launcher surface end to end.

use std::process::Command;

fn bin() -> Command {
    Command::new(env!("CARGO_BIN_EXE_dcf-pca"))
}

#[test]
fn help_lists_commands() {
    let out = bin().arg("help").output().unwrap();
    assert!(out.status.success());
    let text = String::from_utf8_lossy(&out.stdout);
    for cmd in ["solve", "generate", "serve", "worker", "simulate", "experiment", "artifacts-check"]
    {
        assert!(text.contains(cmd), "help missing {cmd}");
    }
}

#[test]
fn simulate_small_seed_range_passes() {
    let out = bin().args(["simulate", "--seeds", "0..2"]).output().unwrap();
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(
        out.status.success(),
        "simulate failed:\n{text}\n{}",
        String::from_utf8_lossy(&out.stderr)
    );
    assert!(text.contains("2 seed(s): 2 ok, 0 failed"), "unexpected summary:\n{text}");
}

#[test]
fn unknown_command_exits_nonzero() {
    let out = bin().arg("frobnicate").output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn solve_small_dcf_and_csv() {
    let dir = std::env::temp_dir().join(format!("dcfpca-cli-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("curve.csv");
    let out = bin()
        .args([
            "solve", "--algorithm", "dcf-pca", "--n", "60", "--rank", "3", "--clients", "5",
            "--rounds", "15", "--csv",
        ])
        .arg(&csv)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("DCF-PCA: final err"), "{stdout}");
    let curve = std::fs::read_to_string(&csv).unwrap();
    assert!(curve.starts_with("iter,err"));
    assert_eq!(curve.lines().count(), 16, "header + 15 rounds");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn solve_accepts_coordinator_knobs() {
    // --participation / --compression / --round-timeout reach the driver
    let out = bin()
        .args([
            "solve", "--algorithm", "dcf-pca", "--n", "50", "--rank", "2", "--clients", "5",
            "--rounds", "20", "--participation", "0.6", "--compression", "int8",
            "--round-timeout", "30",
        ])
        .output()
        .unwrap();
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stderr: {stderr}");
    assert!(String::from_utf8_lossy(&out.stdout).contains("DCF-PCA: final err"));
    // bad values are rejected up front
    for bad in [
        vec!["--participation", "1.5"],
        vec!["--compression", "zip"],
        vec!["--round-timeout", "-1"],
    ] {
        let mut args = vec!["solve", "--algorithm", "dcf-pca", "--n", "40", "--rounds", "5"];
        args.extend(bad.clone());
        let out = bin().args(&args).output().unwrap();
        assert!(!out.status.success(), "expected rejection of {bad:?}");
    }
}

#[test]
fn solve_all_centralized_algorithms() {
    for algo in ["cf-pca", "apgm", "alm"] {
        let out = bin()
            .args(["solve", "--algorithm", algo, "--n", "50", "--rank", "2", "--iters", "40"])
            .output()
            .unwrap();
        assert!(out.status.success(), "{algo} failed: {}", String::from_utf8_lossy(&out.stderr));
        let stdout = String::from_utf8_lossy(&out.stdout);
        assert!(stdout.contains("final err"), "{algo}: {stdout}");
    }
}

#[test]
fn generate_shards_then_solve_streams_them() {
    // the out-of-core smoke path CI runs on the release binary:
    // generate per-client shards + manifest, then a distributed solve
    // whose clients stream their own shards lazily from disk
    let dir = std::env::temp_dir().join(format!("dcfpca-shard-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let prefix = dir.join("fed");
    let out = bin()
        .args(["generate", "--n", "60", "--rank", "3", "--seed", "7", "--format", "shard",
            "--shards", "4", "--out"])
        .arg(&prefix)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let manifest = dir.join("fed.manifest.json");
    assert!(manifest.exists(), "manifest not written");
    for i in 0..4 {
        assert!(dir.join(format!("fed.shard{i}.dcfshard")).exists(), "shard {i} missing");
    }

    let out = bin()
        .args(["solve", "--algorithm", "dcf-pca", "--n", "60", "--rank", "3", "--clients", "4",
            "--rounds", "20", "--data"])
        .arg(&manifest)
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "stdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("DCF-PCA (streamed): final err"), "{stdout}");

    // genuinely out-of-core mode: --rank works without --n (the shape
    // comes from the manifest) and --no-truth skips regeneration
    let out = bin()
        .args(["solve", "--algorithm", "dcf-pca", "--rank", "3", "--rounds", "5", "--no-truth",
            "--data"])
        .arg(&manifest)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    assert!(String::from_utf8_lossy(&out.stdout).contains("DCF-PCA (streamed)"));

    // streaming is refused for centralized algorithms
    let out = bin()
        .args(["solve", "--algorithm", "alm", "--data"])
        .arg(&manifest)
        .output()
        .unwrap();
    assert!(!out.status.success(), "--data must be dcf-pca only");
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn generate_writes_matrix_and_truth() {
    let dir = std::env::temp_dir().join(format!("dcfpca-gen-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let out_path = dir.join("m.csv");
    let out = bin()
        .args(["generate", "--n", "20", "--rank", "2", "--seed", "9", "--truth", "--out"])
        .arg(&out_path)
        .output()
        .unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    let m = dcf_pca::cli::commands::generate::read_matrix_csv(out_path.to_str().unwrap()).unwrap();
    assert_eq!(m.shape(), (20, 20));
    let l0 = dcf_pca::cli::commands::generate::read_matrix_csv(
        &format!("{}.l0.csv", out_path.display()),
    )
    .unwrap();
    let s0 = dcf_pca::cli::commands::generate::read_matrix_csv(
        &format!("{}.s0.csv", out_path.display()),
    )
    .unwrap();
    // M = L0 + S0 (up to CSV round-trip precision)
    let recomposed = &l0 + &s0;
    assert!((&recomposed - &m).frob_norm() < 1e-6);
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn solve_rejects_bad_flags() {
    let out = bin().args(["solve", "--bogus"]).output().unwrap();
    assert!(!out.status.success());
    assert!(String::from_utf8_lossy(&out.stderr).contains("unknown option"));
}

#[test]
fn solve_threads_knob_is_result_invariant() {
    // --threads sizes the process-wide pool; the panel pipeline's slot
    // decomposition makes the printed final error identical at any width
    let run = |threads: &str| {
        let out = bin()
            .args([
                "solve", "--algorithm", "cf-pca", "--n", "80", "--rank", "3", "--iters", "25",
                "--threads", threads,
            ])
            .output()
            .unwrap();
        assert!(out.status.success(), "t={threads}: {}", String::from_utf8_lossy(&out.stderr));
        let stdout = String::from_utf8_lossy(&out.stdout).to_string();
        let err_line = stdout
            .lines()
            .find(|l| l.contains("final err"))
            .unwrap_or_else(|| panic!("t={threads}: no final err in {stdout}"))
            .to_string();
        // "CF-PCA: final err 1.23e-4 after N iterations in <wall>" —
        // compare everything but the wall time
        err_line.split(" in ").next().unwrap().to_string()
    };
    assert_eq!(run("1"), run("2"));
    // zero is rejected up front
    let out = bin().args(["solve", "--n", "20", "--threads", "0"]).output().unwrap();
    assert!(!out.status.success());
}

#[test]
fn config_file_run() {
    let dir = std::env::temp_dir().join(format!("dcfpca-cfg-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let cfg_path = dir.join("run.toml");
    std::fs::write(
        &cfg_path,
        r#"
name = "itest"
algorithm = "dcf-pca"
[problem]
n = 50
rank = 2
seed = 3
[dcf]
clients = 5
rounds = 10
"#,
    )
    .unwrap();
    let out = bin().args(["solve", "--config"]).arg(&cfg_path).output().unwrap();
    assert!(out.status.success(), "{}", String::from_utf8_lossy(&out.stderr));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn serve_and_worker_over_tcp() {
    // spawn the server process, then 2 worker processes, on an ephemeral
    // port; tiny problem so the whole thing finishes in seconds.
    let port = 17431 + (std::process::id() % 1000) as u16;
    let addr = format!("127.0.0.1:{port}");
    let mut server = bin()
        .args([
            "serve", "--listen", &addr, "--clients", "2", "--n", "40", "--rank", "2",
            "--rounds", "8", "--seed", "5",
        ])
        .stdout(std::process::Stdio::piped())
        .spawn()
        .unwrap();
    std::thread::sleep(std::time::Duration::from_millis(400));
    let workers: Vec<_> = (0..2)
        .map(|id| {
            // workers must connect in id order (documented demo-launcher
            // constraint); stagger them
            std::thread::sleep(std::time::Duration::from_millis(150 * id as u64));
            bin()
                .args([
                    "worker", "--connect", &addr, "--id", &id.to_string(), "--clients", "2",
                    "--n", "40", "--rank", "2", "--seed", "5",
                ])
                .spawn()
                .unwrap()
        })
        .collect();
    let status = server.wait().unwrap();
    assert!(status.success());
    let mut out = String::new();
    use std::io::Read as _;
    server.stdout.take().unwrap().read_to_string(&mut out).unwrap();
    assert!(out.contains("run complete"), "{out}");
    assert!(out.contains("final tracked err"), "{out}");
    for mut w in workers {
        assert!(w.wait().unwrap().success());
    }
}
