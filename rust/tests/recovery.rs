//! Cross-algorithm integration: all four solvers recover the paper's
//! synthetic instances, and their behaviours relate the way §4 claims.

use dcf_pca::algorithms::{Alm, Apgm, CfPca, RpcaSolver, Schedule, StopCriteria};
use dcf_pca::coordinator::driver::{run_dcf_pca, DcfPcaConfig};
use dcf_pca::rpca::metrics::singular_value_error;
use dcf_pca::rpca::problem::ProblemSpec;

#[test]
fn all_four_algorithms_recover_the_same_instance() {
    let spec = ProblemSpec::square(80, 4, 0.05);
    let problem = spec.generate(1);

    let alm = Alm::new().solve(&problem.observed, Some(&problem));
    assert!(alm.final_error.unwrap() < 1e-5, "ALM {:?}", alm.final_error);

    let apgm = Apgm::new()
        .with_stop(StopCriteria { max_iters: 300, tol: 1e-8 })
        .solve(&problem.observed, Some(&problem));
    assert!(apgm.final_error.unwrap() < 1e-3, "APGM {:?}", apgm.final_error);

    let cf = CfPca::new(80, 80, 4)
        .with_stop(StopCriteria { max_iters: 80, tol: 1e-9 })
        .solve(&problem.observed, Some(&problem));
    assert!(cf.final_error.unwrap() < 1e-3, "CF-PCA {:?}", cf.final_error);

    let cfg = DcfPcaConfig::default_for(&spec).with_clients(8).with_rounds(50);
    let dcf = run_dcf_pca(&problem, &cfg).unwrap();
    assert!(dcf.final_error.unwrap() < 1e-3, "DCF-PCA {:?}", dcf.final_error);
}

#[test]
fn dcf_with_one_client_matches_cf_pca_exactly() {
    // E = 1, identical constant schedule, identical seeds ⇒ Algorithm 1
    // degenerates to the centralized iteration: the trajectories must be
    // bit-identical (both f64 native kernels).
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(2);
    let eta = 5e-3;
    let rounds = 15;

    let cfg = DcfPcaConfig::default_for(&spec)
        .with_clients(1)
        .with_rounds(rounds)
        .with_k_local(1)
        .with_schedule(Schedule::Const { eta })
        .with_seed(77);
    let mut cfg = cfg;
    cfg.polish_sweeps = 0;
    let dcf = run_dcf_pca(&problem, &cfg).unwrap();

    let mut cf = CfPca::new(40, 40, 2)
        .with_schedule(Schedule::Const { eta })
        .with_stop(StopCriteria { max_iters: rounds, tol: 0.0 })
        .with_seed(77);
    cf.polish_sweeps = 0;
    let cf_res = cf.solve(&problem.observed, Some(&problem));

    // same per-iteration error trajectory
    let dcf_curve = dcf.error_curve();
    let cf_curve = cf_res.error_curve();
    assert_eq!(dcf_curve.len(), cf_curve.len());
    for ((_, a), (_, b)) in dcf_curve.iter().zip(&cf_curve) {
        assert!(
            (a - b).abs() <= 1e-12 * b.max(1e-30),
            "trajectories diverged: {a} vs {b}"
        );
    }
}

#[test]
fn phase_boundary_hard_instances_fail() {
    // paper Fig. 2: beyond r ≈ 0.15n and s ≈ 0.2 recovery breaks down.
    // r = 0.25n, s = 0.35 is far past the boundary.
    let spec = ProblemSpec::square(80, 20, 0.35);
    let problem = spec.generate(3);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(8).with_rounds(50);
    let res = run_dcf_pca(&problem, &cfg).unwrap();
    assert!(
        res.final_error.unwrap() > 1e-2,
        "impossible instance should not be recovered: {:?}",
        res.final_error
    );
}

#[test]
fn easy_phase_cell_recovers_harder_one_does_not_diverge() {
    // middle of the recoverable region: s=0.15, r=0.075n
    let spec = ProblemSpec::square(80, 6, 0.15);
    let problem = spec.generate(4);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(8).with_rounds(60);
    let res = run_dcf_pca(&problem, &cfg).unwrap();
    assert!(res.final_error.unwrap() < 1e-2, "err {:?}", res.final_error);
}

#[test]
fn upper_bound_rank_matches_table1_band() {
    // n=200 row of Table 1: paper reports 0.0286; accept the same order.
    let spec = ProblemSpec::square(200, 10, 0.05);
    let problem = spec.generate(42);
    let mut cfg = DcfPcaConfig::default_for(&spec).with_clients(10).with_rounds(50);
    cfg.hyper.rank = 20; // p = 2r
    let res = run_dcf_pca(&problem, &cfg).unwrap();
    let sv = singular_value_error(&res.l, &problem.l0, 10);
    assert!(sv.relative < 0.12, "σ error {} (paper: 0.0286)", sv.relative);
    assert!(sv.tail_ratio < 0.2, "tail ratio {}", sv.tail_ratio);
}

#[test]
fn alm_beats_factorization_on_accuracy_at_small_scale() {
    // the convex baseline with exact SVD should reach deeper accuracy —
    // the trade the paper describes (accuracy vs distributability)
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(5);
    let alm = Alm::new().solve(&problem.observed, Some(&problem));
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(6).with_rounds(40);
    let dcf = run_dcf_pca(&problem, &cfg).unwrap();
    assert!(alm.final_error.unwrap() < dcf.final_error.unwrap());
}
