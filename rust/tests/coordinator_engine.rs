//! RoundEngine integration: the sans-I/O state machine driven purely
//! from in-memory events (no sockets, no channels, no clock), plus the
//! straggler/elasticity behavior of the reactor-driven paths.

use std::collections::{BTreeMap, VecDeque};
use std::time::Duration;

use dcf_pca::algorithms::factor::{polish_sweep, ClientState, FactorHyper};
use dcf_pca::coordinator::client::FaultPlan;
use dcf_pca::coordinator::driver::{run_dcf_pca, DcfPcaConfig};
use dcf_pca::coordinator::engine::{Action, RoundEngine};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::coordinator::protocol::{restamp_seq, ToClient, ToServer};
use dcf_pca::coordinator::server::{FaultPolicy, ServerConfig, ServerOutcome};
use dcf_pca::coordinator::Compression;
use dcf_pca::linalg::{matmul_nt, Mat, Workspace};
use dcf_pca::rpca::partition::ColumnPartition;
use dcf_pca::rpca::problem::{ProblemSpec, RpcaProblem};
use dcf_pca::runtime::pool;

// ---------------------------------------------------------------------------
// in-memory federation: a client that is itself sans-I/O
// ---------------------------------------------------------------------------

/// Mirrors `run_client` exactly (same state/workspace/polish sequence),
/// but produces outbound messages into a queue instead of a channel —
/// so an engine test never touches a transport or a clock.
struct SimClient {
    id: u32,
    job: u32,
    m_block: Mat,
    hyper: FactorHyper,
    n_frac: f64,
    polish_sweeps: usize,
    truth: Option<(Mat, Mat)>,
    state: ClientState,
    ws: Workspace,
    kernel: NativeKernel,
    outbox: VecDeque<Vec<u8>>,
}

impl SimClient {
    fn new(
        id: usize,
        job: u32,
        m_block: Mat,
        hyper: FactorHyper,
        n_frac: f64,
        truth: Option<(Mat, Mat)>,
    ) -> Self {
        let (m, n_i) = m_block.shape();
        let mut outbox = VecDeque::new();
        outbox.push_back(
            ToServer::Hello { client: id as u32, cols: n_i as u64, token: 0, span: 1 }
                .encode_with(job, Compression::None),
        );
        SimClient {
            id: id as u32,
            job,
            m_block,
            hyper,
            n_frac,
            polish_sweeps: 3,
            truth,
            state: ClientState::zeros(m, n_i, hyper.rank),
            ws: Workspace::new(m, n_i, hyper.rank),
            kernel: NativeKernel::new(),
            outbox,
        }
    }

    fn handle(&mut self, bytes: &[u8]) {
        let (job, msg) = ToClient::decode_job(bytes).unwrap();
        assert_eq!(job, self.job, "client {} got a message for job {job}", self.id);
        match msg {
            ToClient::Round { round, k_local, eta, u } => {
                let mut u = u;
                let out = self
                    .kernel
                    .local_epoch(
                        &mut u,
                        &self.m_block,
                        &mut self.state,
                        &self.hyper,
                        self.n_frac,
                        eta,
                        k_local as usize,
                        &mut self.ws,
                    )
                    .unwrap();
                let err_num = match &self.truth {
                    Some((l0, s0)) => {
                        let l_i = matmul_nt(&u, &self.state.v);
                        (&l_i - l0).frob_norm_sq() + (&self.state.s - s0).frob_norm_sq()
                    }
                    None => f64::NAN,
                };
                self.outbox.push_back(
                    ToServer::Update {
                        client: self.id,
                        round,
                        u,
                        count: 1,
                        cols: self.m_block.cols() as u64,
                        grad_sum: out.grad_norm,
                        lip_max: out.lipschitz,
                        err_num_sum: err_num,
                        secs_max: 0.0,
                        secs_sum: 0.0,
                    }
                    .encode_with(self.job, Compression::None),
                );
            }
            ToClient::Finish { reveal, final_u } => {
                for _ in 0..self.polish_sweeps {
                    polish_sweep(
                        &final_u,
                        &self.m_block,
                        &mut self.state,
                        &self.hyper,
                        pool::global(),
                        &mut self.ws,
                    )
                    .expect("polish sweep failed");
                }
                let reply = if reveal {
                    let l_i = matmul_nt(&final_u, &self.state.v);
                    ToServer::Reveal { client: self.id, l: l_i, s: self.state.s.clone() }
                } else {
                    ToServer::Withhold { client: self.id }
                };
                self.outbox
                    .push_back(reply.encode_with(self.job, Compression::None));
            }
            // this in-memory client never reconnects, so the session
            // token is irrelevant to it
            ToClient::Welcome { .. } => {}
            ToClient::Shutdown => {}
        }
    }
}

/// Feed the federation to completion. `order[k]` decides whose pending
/// messages enter the engine first after each step — i.e. the simulated
/// arrival order. `late_hello = Some((ep, after))` withholds one client's
/// Hello until `after` inbound messages have been processed (elastic
/// join mid-run).
fn drive_in_memory(
    engine: &mut RoundEngine,
    clients: &mut [SimClient],
    order: &[usize],
    late_hello: Option<(usize, usize)>,
) {
    let mut inbound: VecDeque<(usize, Vec<u8>)> = VecDeque::new();
    let late_ep = late_hello.map(|(ep, _)| ep);
    for &i in order {
        if Some(i) != late_ep {
            while let Some(m) = clients[i].outbox.pop_front() {
                inbound.push_back((i, m));
            }
        }
    }
    // a synthetic clock the engine never reads on its own
    let mut now = Duration::from_millis(1);
    let mut processed = 0usize;
    let mut joined = late_hello.is_none();
    let mut guard = 0usize;
    while !engine.all_done() {
        guard += 1;
        assert!(guard < 200_000, "engine made no progress");
        if !joined {
            if let Some((ep, after)) = late_hello {
                if processed >= after {
                    while let Some(m) = clients[ep].outbox.pop_front() {
                        inbound.push_back((ep, m));
                    }
                    joined = true;
                }
            }
        }
        let (ep, bytes) = inbound.pop_front().expect("engine idle but not done");
        processed += 1;
        now += Duration::from_millis(1);
        let actions = engine.handle_message(ep, &bytes, now);
        for a in actions {
            match a {
                Action::Send { ep, bytes } => clients[ep].handle(&bytes),
                Action::Broadcast { peers, body } => {
                    for (ep, seq) in peers {
                        let mut bytes = body.as_ref().clone();
                        restamp_seq(&mut bytes, seq);
                        clients[ep].handle(&bytes);
                    }
                }
                Action::Upstream { .. } => unreachable!("root jobs never emit Upstream"),
                Action::Close { .. } | Action::JobDone { .. } => {}
            }
        }
        for &i in order {
            if joined || Some(i) != late_ep {
                while let Some(m) = clients[i].outbox.pop_front() {
                    inbound.push_back((i, m));
                }
            }
        }
    }
}

/// Driver-equivalent ServerConfig for a generated problem.
fn server_cfg_for(problem: &RpcaProblem, cfg: &DcfPcaConfig) -> ServerConfig {
    let mut s = ServerConfig::new(problem.spec.m, cfg.hyper.rank, cfg.rounds, cfg.k_local);
    s.schedule = cfg.schedule;
    s.aggregation = cfg.aggregation;
    s.privacy = cfg.privacy.clone();
    s.seed = cfg.seed;
    s.round_timeout = cfg.round_timeout;
    s.fault_policy = cfg.fault_policy;
    s.err_denominator = Some(problem.l0.frob_norm_sq() + problem.s0.frob_norm_sq());
    s.compression = cfg.compression;
    s.participation = cfg.participation;
    s
}

fn sim_clients(problem: &RpcaProblem, cfg: &DcfPcaConfig, e: usize, job: u32) -> Vec<SimClient> {
    let n = problem.spec.n;
    let partition = ColumnPartition::even(n, e);
    (0..e)
        .map(|i| {
            let (a, b) = partition.range(i);
            SimClient::new(
                i,
                job,
                problem.observed.cols_range(a, b),
                cfg.hyper,
                (b - a) as f64 / n as f64,
                Some((problem.l0.cols_range(a, b), problem.s0.cols_range(a, b))),
            )
        })
        .collect()
}

/// Eq. 30 error over revealed blocks (post-polish), as the driver
/// assembles it.
fn assembled_error(
    problem: &RpcaProblem,
    partition: &ColumnPartition,
    revealed: &[(usize, Mat, Mat)],
) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, l_i, s_i) in revealed {
        let (a, b) = partition.range(*i);
        let l0 = problem.l0.cols_range(a, b);
        let s0 = problem.s0.cols_range(a, b);
        num += (l_i - &l0).frob_norm_sq() + (s_i - &s0).frob_norm_sq();
        den += l0.frob_norm_sq() + s0.frob_norm_sq();
    }
    num / den
}

// ---------------------------------------------------------------------------
// sans-I/O: full E=4 federation from in-memory events only
// ---------------------------------------------------------------------------

#[test]
fn engine_runs_e4_purely_in_memory_and_matches_driver_bitwise() {
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(7);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(4).with_rounds(40);

    // reference: the threaded in-proc driver (ChannelReactor path)
    let reference = run_dcf_pca(&problem, &cfg).unwrap();
    assert!(reference.final_error.unwrap() < 1e-3);

    // same federation, zero I/O: every event is an in-memory Vec<u8>
    let mut engine = RoundEngine::new();
    engine.add_job(0, server_cfg_for(&problem, &cfg), 4);
    let mut clients = sim_clients(&problem, &cfg, 4, 0);
    drive_in_memory(&mut engine, &mut clients, &[0, 1, 2, 3], None);
    let outcome: ServerOutcome = engine.take_result(0).unwrap().unwrap();

    assert_eq!(outcome.u, reference.u, "sans-I/O engine diverged from the driver");
    assert_eq!(outcome.rounds.len(), 40);
    assert!(outcome.rounds.last().unwrap().err.unwrap() < 1e-3);
    assert_eq!(outcome.revealed.len(), 4);
    assert_eq!(outcome.client_cols, vec![15; 4]);
}

#[test]
fn engine_aggregate_is_bitwise_invariant_to_arrival_order() {
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(9);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(4).with_rounds(12);

    let mut results = Vec::new();
    for order in [[0, 1, 2, 3], [3, 1, 0, 2], [2, 3, 1, 0]] {
        let mut engine = RoundEngine::new();
        engine.add_job(0, server_cfg_for(&problem, &cfg), 4);
        let mut clients = sim_clients(&problem, &cfg, 4, 0);
        drive_in_memory(&mut engine, &mut clients, &order, None);
        results.push(engine.take_result(0).unwrap().unwrap());
    }
    // slot-ordered reduction ⇒ same U and same telemetry sums, bitwise,
    // no matter which client's update lands first
    assert_eq!(results[0].u, results[1].u);
    assert_eq!(results[0].u, results[2].u);
    for k in 1..results.len() {
        for (a, b) in results[0].rounds.iter().zip(&results[k].rounds) {
            assert_eq!(a.err, b.err);
            assert_eq!(a.mean_grad_norm, b.mean_grad_norm);
            assert_eq!(a.dispersion, b.dispersion);
        }
    }
}

#[test]
fn engine_elastic_join_enters_at_next_round_boundary() {
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(7);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(5).with_rounds(40);

    let mut engine = RoundEngine::new();
    // only 4 founding members; the 5th Hello arrives mid-run
    engine.add_job(0, server_cfg_for(&problem, &cfg), 4);
    let mut clients = sim_clients(&problem, &cfg, 5, 0);
    // 4 hellos + 3 rounds × 4 updates = 16 messages, then client 4 knocks
    drive_in_memory(&mut engine, &mut clients, &[0, 1, 2, 3, 4], Some((4, 16)));
    let outcome = engine.take_result(0).unwrap().unwrap();

    assert_eq!(outcome.client_cols.len(), 5, "late joiner registered");
    assert_eq!(outcome.revealed.len(), 5, "late joiner revealed its block");
    let participants: Vec<usize> = outcome.rounds.iter().map(|r| r.participants).collect();
    assert_eq!(participants[0], 4, "founding rounds run with 4 clients");
    assert_eq!(*participants.last().unwrap(), 5, "joiner active after the boundary");
    assert!(participants.windows(2).all(|w| w[0] <= w[1]), "{participants:?}");
    // recovery still lands: U saw all blocks for most of the run, and
    // polish refits every revealed block against the final U
    let partition = ColumnPartition::even(spec.n, 5);
    let err = assembled_error(&problem, &partition, &outcome.revealed);
    assert!(err < 5e-3, "elastic-join recovery err {err}");
}

// ---------------------------------------------------------------------------
// session hardening: duplicate / replayed / stale frames, mid-round resume
// ---------------------------------------------------------------------------

const HARD_M: usize = 6;
const HARD_RANK: usize = 2;

/// Protocol-level federation for hardening tests: every frame is crafted
/// (and replayable) by hand with an explicit envelope sequence number,
/// and updates carry a deterministic per-(client, round) U so bitwise
/// comparisons across runs are meaningful with no numerics in the loop.
fn hardening_engine(policy: FaultPolicy, rounds: usize, clients: usize) -> RoundEngine {
    let mut cfg = ServerConfig::new(HARD_M, HARD_RANK, rounds, 1);
    cfg.fault_policy = policy;
    cfg.round_timeout = Duration::from_secs(3600);
    let mut engine = RoundEngine::new();
    engine.add_job(0, cfg, clients);
    for ep in 0..clients {
        engine.on_connect(ep);
    }
    engine
}

fn hello_frame(client: u32, token: u64, seq: u32) -> Vec<u8> {
    ToServer::Hello { client, cols: 3, token, span: 1 }.encode_seq(0, seq, Compression::None)
}

fn update_frame(client: u32, round: u32, seq: u32) -> Vec<u8> {
    let u = Mat::from_fn(HARD_M, HARD_RANK, |i, j| {
        (client as f64 + 1.0) * 0.25 + round as f64 * 0.125 + (i * HARD_RANK + j) as f64 * 1e-3
    });
    ToServer::Update {
        client,
        round,
        u,
        count: 1,
        cols: 3,
        grad_sum: 1.0,
        lip_max: 1.0,
        err_num_sum: f64::NAN,
        secs_max: 0.0,
        secs_sum: 0.0,
    }
    .encode_seq(0, seq, Compression::None)
}

fn withhold_frame(client: u32, seq: u32) -> Vec<u8> {
    ToServer::Withhold { client }.encode_seq(0, seq, Compression::None)
}

/// Raw payloads queued for `ep` — direct `Send` frames plus the
/// endpoint's share of any `Broadcast`, restamped with its seq.
fn raw_sends_to(actions: &[Action], ep: usize) -> Vec<Vec<u8>> {
    actions
        .iter()
        .flat_map(|a| match a {
            Action::Send { ep: e, bytes } if *e == ep => vec![bytes.clone()],
            Action::Broadcast { peers, body } => peers
                .iter()
                .filter(|(e, _)| *e == ep)
                .map(|&(_, seq)| {
                    let mut bytes = body.as_ref().clone();
                    restamp_seq(&mut bytes, seq);
                    bytes
                })
                .collect(),
            _ => vec![],
        })
        .collect()
}

fn sends_to(actions: &[Action], ep: usize) -> Vec<ToClient> {
    raw_sends_to(actions, ep)
        .iter()
        .map(|b| ToClient::decode_job(b).unwrap().1)
        .collect()
}

fn welcome_token(actions: &[Action], ep: usize) -> u64 {
    sends_to(actions, ep)
        .into_iter()
        .find_map(|m| match m {
            ToClient::Welcome { token } => Some(token),
            _ => None,
        })
        .expect("no Welcome queued for the endpoint")
}

/// Mechanically answer every outstanding engine send (deterministic
/// updates, Withhold finishes) until the job completes. `eps` maps each
/// live endpoint to its client id and last-used upstream seq.
fn run_to_outcome(
    engine: &mut RoundEngine,
    eps: &mut BTreeMap<usize, (u32, u32)>,
    mut inbox: Vec<Action>,
) -> ServerOutcome {
    let mut now = Duration::from_millis(100);
    let mut guard = 0usize;
    while !engine.all_done() {
        guard += 1;
        assert!(guard < 10_000, "hardening federation made no progress");
        let mut next = Vec::new();
        for a in inbox.drain(..) {
            let frames: Vec<(usize, Vec<u8>)> = match a {
                Action::Send { ep, bytes } => vec![(ep, bytes)],
                Action::Broadcast { peers, body } => peers
                    .into_iter()
                    .map(|(ep, seq)| {
                        let mut bytes = body.as_ref().clone();
                        restamp_seq(&mut bytes, seq);
                        (ep, bytes)
                    })
                    .collect(),
                _ => continue,
            };
            for (ep, bytes) in frames {
                let (_, msg) = ToClient::decode_job(&bytes).unwrap();
                now += Duration::from_millis(1);
                match msg {
                    ToClient::Round { round, .. } => {
                        let e = eps.get_mut(&ep).expect("send to unknown endpoint");
                        e.1 += 1;
                        next.extend(engine.handle_message(ep, &update_frame(e.0, round, e.1), now));
                    }
                    ToClient::Finish { .. } => {
                        let e = eps.get_mut(&ep).expect("send to unknown endpoint");
                        e.1 += 1;
                        next.extend(engine.handle_message(ep, &withhold_frame(e.0, e.1), now));
                    }
                    ToClient::Welcome { .. } | ToClient::Shutdown => {}
                }
            }
        }
        inbox = next;
    }
    engine.take_result(0).unwrap().unwrap()
}

#[test]
fn duplicate_hello_frame_is_shed_under_both_policies() {
    for policy in [FaultPolicy::Strict, FaultPolicy::SkipMissing] {
        let mut engine = hardening_engine(policy, 1, 2);
        let now = Duration::from_millis(1);
        let h0 = hello_frame(0, 0, 1);
        let first = engine.handle_message(0, &h0, now);
        assert_ne!(welcome_token(&first, 0), 0, "Welcome carries a nonzero token");
        // the network replays the session's own Hello on the same
        // connection: the binding already exists, so the repeat is shed
        // without side effects — even under Strict
        let dup = engine.handle_message(0, &h0, now);
        assert!(dup.is_empty(), "{policy:?}: duplicate Hello answered with {dup:?}");
        let opened = engine.handle_message(1, &hello_frame(1, 0, 1), now);
        assert!(
            sends_to(&opened, 0).iter().any(|m| matches!(m, ToClient::Round { round: 0, .. })),
            "{policy:?}: round 0 did not open for the duplicated member"
        );
        let mut eps = BTreeMap::from([(0usize, (0u32, 1u32)), (1usize, (1u32, 1u32))]);
        let outcome = run_to_outcome(&mut engine, &mut eps, opened);
        assert_eq!(outcome.rounds.len(), 1);
        assert_eq!(outcome.rounds[0].participants, 2, "{policy:?}");
    }
}

#[test]
fn replayed_update_is_dropped_under_both_policies() {
    for policy in [FaultPolicy::Strict, FaultPolicy::SkipMissing] {
        let mut engine = hardening_engine(policy, 2, 2);
        let now = Duration::from_millis(1);
        let mut opened = engine.handle_message(0, &hello_frame(0, 0, 1), now);
        opened.extend(engine.handle_message(1, &hello_frame(1, 0, 1), now));
        assert_eq!(engine.round_of(0), Some(0));

        let up = update_frame(0, 0, 2);
        assert!(engine.handle_message(0, &up, now).is_empty());
        // a reconnect re-send the engine already processed: the envelope
        // seq was accepted once, so the replay is shed — it must not
        // double-count client 0 or fail the job under Strict
        let replay = engine.handle_message(0, &up, now);
        assert!(replay.is_empty(), "{policy:?}: replayed update answered with {replay:?}");
        assert_eq!(engine.round_of(0), Some(0), "{policy:?}: replay advanced the round");

        let closed = engine.handle_message(1, &update_frame(1, 0, 2), now);
        assert_eq!(engine.round_of(0), Some(1), "{policy:?}: round 0 did not close");
        let mut eps = BTreeMap::from([(0usize, (0u32, 2u32)), (1usize, (1u32, 2u32))]);
        let outcome = run_to_outcome(&mut engine, &mut eps, closed);
        assert_eq!(outcome.rounds.len(), 2);
        assert!(outcome.rounds.iter().all(|r| r.participants == 2), "{policy:?}");
    }
}

#[test]
fn stale_round_frames_are_ignored_under_both_policies() {
    for policy in [FaultPolicy::Strict, FaultPolicy::SkipMissing] {
        let mut engine = hardening_engine(policy, 2, 2);
        let now = Duration::from_millis(1);
        let mut opened = engine.handle_message(0, &hello_frame(0, 0, 1), now);
        opened.extend(engine.handle_message(1, &hello_frame(1, 0, 1), now));
        drop(opened);
        assert!(engine.handle_message(0, &update_frame(0, 0, 2), now).is_empty());
        let _round1 = engine.handle_message(1, &update_frame(1, 0, 2), now);
        assert_eq!(engine.round_of(0), Some(1));

        // a client-side retransmit of its round-0 answer arriving after
        // the cutover, re-enveloped with a fresh seq: stale, ignored
        let stale = engine.handle_message(0, &update_frame(0, 0, 3), now);
        assert!(stale.is_empty(), "{policy:?}: stale update answered with {stale:?}");
        assert_eq!(engine.round_of(0), Some(1), "{policy:?}: stale update moved the round");

        // close round 1 normally — client 0's seq continues past the
        // burned retransmit seq
        assert!(engine.handle_message(0, &update_frame(0, 1, 4), now).is_empty());
        let finish = engine.handle_message(1, &update_frame(1, 1, 3), now);
        assert!(
            sends_to(&finish, 0).iter().any(|m| matches!(m, ToClient::Finish { .. })),
            "{policy:?}: finish phase did not open"
        );
        // an update landing during the finish phase is out-of-phase:
        // equally ignored rather than adjudicated by FaultPolicy
        let late = engine.handle_message(0, &update_frame(0, 1, 5), now);
        assert!(late.is_empty(), "{policy:?}: out-of-phase update answered with {late:?}");

        let mut eps = BTreeMap::from([(0usize, (0u32, 5u32)), (1usize, (1u32, 3u32))]);
        let outcome = run_to_outcome(&mut engine, &mut eps, finish);
        assert_eq!(outcome.rounds.len(), 2);
        assert!(outcome.rounds.iter().all(|r| r.participants == 2), "{policy:?}");
        assert_eq!(outcome.withheld, vec![0, 1]);
    }
}

#[test]
fn mid_round_resume_rejoins_without_a_cut_and_stays_bitwise_identical() {
    let run = |flap: bool| -> ServerOutcome {
        let mut engine = hardening_engine(FaultPolicy::SkipMissing, 3, 2);
        let mut now = Duration::from_millis(1);
        let mut opened = engine.handle_message(0, &hello_frame(0, 0, 1), now);
        opened.extend(engine.handle_message(1, &hello_frame(1, 0, 1), now));
        let token = welcome_token(&opened, 1);
        let round0_to_1 = raw_sends_to(&opened, 1)
            .into_iter()
            .find(|b| matches!(ToClient::decode_job(b).unwrap().1, ToClient::Round { .. }))
            .expect("no round 0 broadcast for client 1");

        // client 0 answers round 0 either way
        assert!(engine.handle_message(0, &update_frame(0, 0, 2), now).is_empty());

        let (ep1, seq1, closed) = if flap {
            // client 1's link drops before its reply: grace window opens
            now += Duration::from_millis(5);
            let dropped = engine.on_disconnect(1, now);
            assert!(dropped.is_empty(), "disconnect inside grace is silent: {dropped:?}");
            assert_eq!(engine.round_of(0), Some(0), "grace keeps the round open");
            // ...and the client redials as a fresh endpoint, echoing its
            // session token
            let ep = 7;
            engine.on_connect(ep);
            now += Duration::from_millis(5);
            let resumed = engine.handle_message(ep, &hello_frame(1, token, 2), now);
            assert_eq!(welcome_token(&resumed, ep), token, "live resume keeps the token");
            let redelivered = raw_sends_to(&resumed, ep)
                .into_iter()
                .find(|b| matches!(ToClient::decode_job(b).unwrap().1, ToClient::Round { .. }))
                .expect("resume did not re-deliver the in-flight round");
            use dcf_pca::coordinator::protocol::ENVELOPE_BYTES;
            assert_eq!(
                &redelivered[ENVELOPE_BYTES..],
                &round0_to_1[ENVELOPE_BYTES..],
                "re-delivered Round payload differs from the original broadcast"
            );
            let closed = engine.handle_message(ep, &update_frame(1, 0, 3), now);
            (ep, 3u32, closed)
        } else {
            let closed = engine.handle_message(1, &update_frame(1, 0, 2), now);
            (1usize, 2u32, closed)
        };
        assert_eq!(engine.round_of(0), Some(1), "round 0 closed with both updates");

        let mut eps = BTreeMap::from([(0usize, (0u32, 2u32)), (ep1, (1u32, seq1))]);
        run_to_outcome(&mut engine, &mut eps, closed)
    };

    let reference = run(false);
    let flapped = run(true);
    assert_eq!(flapped.u, reference.u, "resume changed U bitwise");
    assert_eq!(flapped.rounds.len(), reference.rounds.len());
    for (a, b) in reference.rounds.iter().zip(&flapped.rounds) {
        assert_eq!(b.participants, 2, "a recoverable flap cut a client");
        assert_eq!(a.participants, b.participants);
        assert_eq!(a.err, b.err);
        assert_eq!(a.mean_grad_norm, b.mean_grad_norm);
        assert_eq!(a.dispersion, b.dispersion);
    }
}

#[test]
fn stale_session_token_resume_is_refused() {
    // SkipMissing: the impostor endpoint is closed, the member's session
    // is untouched, and the federation completes at full strength
    let mut engine = hardening_engine(FaultPolicy::SkipMissing, 1, 2);
    let now = Duration::from_millis(1);
    let mut opened = engine.handle_message(0, &hello_frame(0, 0, 1), now);
    opened.extend(engine.handle_message(1, &hello_frame(1, 0, 1), now));
    let token = welcome_token(&opened, 1);

    engine.on_connect(9);
    let refused = engine.handle_message(9, &hello_frame(1, token ^ 2, 1), now);
    assert!(
        refused.iter().any(|a| matches!(a, Action::Close { ep: 9 })),
        "stale-token resume not closed: {refused:?}"
    );
    assert!(raw_sends_to(&refused, 9).is_empty(), "impostor got a payload: {refused:?}");
    assert_eq!(engine.round_of(0), Some(0), "refusal must not disturb the job");

    let mut eps = BTreeMap::from([(0usize, (0u32, 1u32)), (1usize, (1u32, 1u32))]);
    let outcome = run_to_outcome(&mut engine, &mut eps, opened);
    assert_eq!(outcome.rounds[0].participants, 2);

    // Strict: the same impostor is a protocol violation that fails the job
    let mut engine = hardening_engine(FaultPolicy::Strict, 1, 2);
    let mut opened = engine.handle_message(0, &hello_frame(0, 0, 1), now);
    opened.extend(engine.handle_message(1, &hello_frame(1, 0, 1), now));
    let token = welcome_token(&opened, 1);
    engine.on_connect(9);
    let failed = engine.handle_message(9, &hello_frame(1, token ^ 2, 1), now);
    assert!(
        failed.iter().any(|a| matches!(a, Action::JobDone { job: 0 })),
        "Strict did not fail the job: {failed:?}"
    );
    assert!(engine.take_result(0).unwrap().is_err(), "Strict accepted a stale token");
}

#[test]
fn engine_multiplexes_concurrent_jobs_over_one_reactor() {
    use dcf_pca::coordinator::client::{run_client, ClientConfig};
    use dcf_pca::coordinator::transport::inproc::pair;
    use dcf_pca::coordinator::transport::reactor::{drive, ChannelReactor};
    use dcf_pca::coordinator::transport::Channel;

    let spec_a = ProblemSpec::square(50, 2, 0.05);
    let spec_b = ProblemSpec::square(40, 3, 0.05);
    let problem_a = spec_a.generate(21);
    let problem_b = spec_b.generate(22);
    let cfg_a = DcfPcaConfig::default_for(&spec_a).with_clients(3).with_rounds(25).with_seed(0xA);
    let cfg_b = DcfPcaConfig::default_for(&spec_b).with_clients(3).with_rounds(30).with_seed(0xB);

    // single-job references
    let ref_a = run_dcf_pca(&problem_a, &cfg_a).unwrap();
    let ref_b = run_dcf_pca(&problem_b, &cfg_b).unwrap();

    // one coordinator, one reactor, six endpoints, two interleaved jobs
    let mut channels: Vec<Box<dyn Channel>> = Vec::new();
    let mut handles = Vec::new();
    for ep in 0..6 {
        let job = (ep % 2) as u32;
        let id = ep / 2;
        let (problem, cfg) = if job == 0 { (&problem_a, &cfg_a) } else { (&problem_b, &cfg_b) };
        let n = problem.spec.n;
        let partition = ColumnPartition::even(n, 3);
        let (a, b) = partition.range(id);
        let client_cfg = ClientConfig {
            id,
            job,
            data: Box::new(problem.observed.cols_range(a, b)),
            hyper: cfg.hyper,
            n_frac: (b - a) as f64 / n as f64,
            polish_sweeps: cfg.polish_sweeps,
            truth: Some((problem.l0.cols_range(a, b), problem.s0.cols_range(a, b))),
            faults: FaultPlan::default(),
            compression: Compression::None,
            dp_sigma: 0.0,
        };
        let (server_side, mut client_side) = pair();
        channels.push(Box::new(server_side));
        handles.push(std::thread::spawn(move || {
            run_client(&mut client_side, client_cfg, &NativeKernel::new())
        }));
    }

    let mut engine = RoundEngine::new();
    engine.add_job(0, server_cfg_for(&problem_a, &cfg_a), 3);
    engine.add_job(1, server_cfg_for(&problem_b, &cfg_b), 3);
    let mut reactor = ChannelReactor::new(&mut channels);
    drive(&mut reactor, &mut engine).unwrap();
    let out_a = engine.take_result(0).unwrap().unwrap();
    let out_b = engine.take_result(1).unwrap().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    // each multiplexed job matches its solo run bitwise
    assert_eq!(out_a.u, ref_a.u);
    assert_eq!(out_b.u, ref_b.u);
    assert_eq!(out_a.rounds.len(), 25);
    assert_eq!(out_b.rounds.len(), 30);
    assert!(out_a.rounds.last().unwrap().err.unwrap() < 5e-2);
    assert!(out_b.rounds.last().unwrap().err.unwrap() < 5e-2);
}

// ---------------------------------------------------------------------------
// stragglers over the real in-proc transport (driver path)
// ---------------------------------------------------------------------------

#[test]
fn straggler_round_time_tracks_max_not_sum() {
    let spec = ProblemSpec::square(64, 2, 0.05);
    let problem = spec.generate(31);
    let e = 8;
    let delay = Duration::from_millis(60);
    let mut cfg = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(4);
    cfg.faults = vec![FaultPlan { reply_delay: Some(delay), ..Default::default() }; e];
    let res = run_dcf_pca(&problem, &cfg).unwrap();

    let mean_round = res.rounds.iter().map(|r| r.round_secs).sum::<f64>() / res.rounds.len() as f64;
    let sum_of_delays = e as f64 * delay.as_secs_f64(); // 0.48 s
    assert!(
        mean_round < 0.5 * sum_of_delays,
        "round time {mean_round:.3}s looks sequential (sum would be {sum_of_delays:.2}s)"
    );
    assert!(
        mean_round >= delay.as_secs_f64() * 0.9,
        "round time {mean_round:.3}s beat the slowest client — impossible"
    );
}

#[test]
fn deterministic_u_regardless_of_which_client_straggles() {
    let spec = ProblemSpec::square(50, 2, 0.05);
    let problem = spec.generate(32);
    let e = 5;
    let base = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(6);

    let mut slow_first = base.clone();
    slow_first.faults = vec![FaultPlan::default(); e];
    slow_first.faults[0].reply_delay = Some(Duration::from_millis(40));

    let mut slow_last = base.clone();
    slow_last.faults = vec![FaultPlan::default(); e];
    slow_last.faults[e - 1].reply_delay = Some(Duration::from_millis(40));

    let a = run_dcf_pca(&problem, &slow_first).unwrap();
    let b = run_dcf_pca(&problem, &slow_last).unwrap();
    let c = run_dcf_pca(&problem, &base).unwrap();
    // arrival order changed; slot-ordered reduction keeps U (and hence
    // L, S) bitwise identical
    assert_eq!(a.u, b.u);
    assert_eq!(a.u, c.u);
    assert_eq!(a.l, b.l);
    assert_eq!(a.s, b.s);
}

#[test]
fn straggler_cut_bounds_round_latency() {
    let spec = ProblemSpec::square(64, 2, 0.05);
    let problem = spec.generate(33);
    let e = 8;
    let deadline = Duration::from_millis(150);
    let delay = Duration::from_millis(400);

    // baseline: no straggler, same deadline
    let mut base = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(6);
    base.fault_policy = FaultPolicy::SkipMissing;
    base.round_timeout = deadline;
    let baseline = run_dcf_pca(&problem, &base).unwrap();
    let base_mean =
        baseline.rounds.iter().map(|r| r.round_secs).sum::<f64>() / baseline.rounds.len() as f64;

    // one client 200 ms late every round: the cut closes each round at
    // the deadline instead of waiting out the straggler
    let mut cfg = base.clone();
    cfg.faults = vec![FaultPlan::default(); e];
    cfg.faults[0].reply_delay = Some(delay);
    let res = run_dcf_pca(&problem, &cfg).unwrap();

    let mean_round = res.rounds.iter().map(|r| r.round_secs).sum::<f64>() / res.rounds.len() as f64;
    assert!(
        mean_round < base_mean + 2.0 * deadline.as_secs_f64(),
        "straggler dominated the round: {mean_round:.3}s vs baseline {base_mean:.3}s"
    );
    assert!(
        mean_round < delay.as_secs_f64(),
        "round waited out the straggler: {mean_round:.3}s"
    );
    // the cut excluded the straggler, not the run: it overshoots every
    // deadline so it can never be a participant, while the healthy
    // majority lands (≤ rather than == tolerates scheduler noise)
    let participants: Vec<usize> = res.rounds.iter().map(|r| r.participants).collect();
    assert!(participants.iter().all(|&p| p <= e - 1), "{participants:?}");
    assert!(participants.iter().any(|&p| p == e - 1), "{participants:?}");
    // hundreds of ms behind per round, it also misses the reveal
    // deadline; the healthy majority reveals
    assert!(res.withheld_clients.contains(&0));
    assert!(res.revealed_clients.len() >= e - 2);
    assert!(!res.revealed_clients.contains(&0));
}

// ---------------------------------------------------------------------------
// reveal-phase faults (regression: used to abort the whole run)
// ---------------------------------------------------------------------------

#[test]
fn reveal_phase_crash_is_withheld_under_skip_missing() {
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(34);
    let mut cfg = DcfPcaConfig::default_for(&spec).with_clients(3).with_rounds(12);
    cfg.fault_policy = FaultPolicy::SkipMissing;
    cfg.round_timeout = Duration::from_secs(5);
    cfg.faults = vec![
        FaultPlan::default(),
        FaultPlan { crash_at_finish: true, ..Default::default() },
        FaultPlan::default(),
    ];
    let res = run_dcf_pca(&problem, &cfg).unwrap();
    // every round ran with all three; only the reveal is missing
    assert!(res.rounds.iter().all(|r| r.participants == 3));
    assert_eq!(res.withheld_clients, vec![1]);
    assert_eq!(res.revealed_clients, vec![0, 2]);
    assert!(res.final_error.unwrap() < 5e-2);
}

#[test]
fn reveal_phase_crash_still_fails_under_strict() {
    let spec = ProblemSpec::square(30, 2, 0.05);
    let problem = spec.generate(35);
    let mut cfg = DcfPcaConfig::default_for(&spec).with_clients(2).with_rounds(5);
    cfg.fault_policy = FaultPolicy::Strict;
    cfg.round_timeout = Duration::from_secs(2);
    cfg.faults = vec![
        FaultPlan { crash_at_finish: true, ..Default::default() },
        FaultPlan::default(),
    ];
    assert!(run_dcf_pca(&problem, &cfg).is_err());
}

// ---------------------------------------------------------------------------
// epoll reactor end-to-end (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod epoll_e2e {
    use super::*;
    use dcf_pca::coordinator::client::{run_client, ClientConfig};
    use dcf_pca::coordinator::transport::reactor::{drive, EpollReactor};
    use dcf_pca::coordinator::transport::tcp::TcpChannel;

    fn spawn_worker(
        addr: String,
        problem: &RpcaProblem,
        partition: &ColumnPartition,
        id: usize,
        faults: FaultPlan,
    ) -> std::thread::JoinHandle<dcf_pca::anyhow::Result<usize>> {
        let spec = problem.spec;
        let (a, b) = partition.range(id);
        let m_block = problem.observed.cols_range(a, b);
        let truth = (problem.l0.cols_range(a, b), problem.s0.cols_range(a, b));
        std::thread::spawn(move || {
            let mut ch = TcpChannel::connect(&addr)?;
            let cfg = ClientConfig {
                id,
                job: 0,
                n_frac: (b - a) as f64 / spec.n as f64,
                data: Box::new(m_block),
                hyper: FactorHyper::default_for(spec.m, spec.n, spec.rank),
                polish_sweeps: 3,
                truth: Some(truth),
                faults,
                compression: Compression::None,
                dp_sigma: 0.0,
            };
            run_client(&mut ch, cfg, &NativeKernel::new())
        })
    }

    fn run_epoll_server(
        listener: std::net::TcpListener,
        cfg: ServerConfig,
        expected: usize,
    ) -> std::thread::JoinHandle<ServerOutcome> {
        std::thread::spawn(move || {
            let mut engine = RoundEngine::new();
            engine.add_job(0, cfg, expected);
            let mut reactor = EpollReactor::new(listener).unwrap();
            drive(&mut reactor, &mut engine).unwrap();
            engine.take_result(0).unwrap().unwrap()
        })
    }

    /// Mirrors `driver::tests::recovers_distributed_small` numerically —
    /// same problem, seed, E, rounds — so the epoll reactor must land the
    /// same sub-1e-3 recovery as the in-proc path.
    #[test]
    fn epoll_reactor_recovers_like_the_inproc_path() {
        let spec = ProblemSpec::square(60, 3, 0.05);
        let problem = spec.generate(7);
        let e = 5;
        let partition = ColumnPartition::even(spec.n, e);
        let dcf = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(40);
        let cfg = server_cfg_for(&problem, &dcf);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = run_epoll_server(listener, cfg, e);
        let workers: Vec<_> = (0..e)
            .map(|id| spawn_worker(addr.clone(), &problem, &partition, id, FaultPlan::default()))
            .collect();

        let outcome = server.join().unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        assert_eq!(outcome.revealed.len(), e);
        let err = assembled_error(&problem, &partition, &outcome.revealed);
        assert!(err < 1e-3, "epoll recovery err {err}");
    }

    #[test]
    fn epoll_reactor_accepts_late_joiner_mid_run() {
        let spec = ProblemSpec::square(60, 3, 0.05);
        let problem = spec.generate(11);
        let blocks = 5; // 4 founding workers + 1 elastic joiner
        let partition = ColumnPartition::even(spec.n, blocks);
        let mut dcf = DcfPcaConfig::default_for(&spec).with_clients(blocks).with_rounds(40);
        dcf.round_timeout = Duration::from_secs(30);
        let cfg = server_cfg_for(&problem, &dcf);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = run_epoll_server(listener, cfg, blocks - 1);

        // founding workers pace the run at ≥20 ms per round so the
        // joiner reliably lands mid-training
        let pace = FaultPlan { reply_delay: Some(Duration::from_millis(20)), ..Default::default() };
        let mut workers: Vec<_> = (0..blocks - 1)
            .map(|id| spawn_worker(addr.clone(), &problem, &partition, id, pace))
            .collect();
        std::thread::sleep(Duration::from_millis(250));
        workers.push(spawn_worker(
            addr.clone(),
            &problem,
            &partition,
            blocks - 1,
            FaultPlan::default(),
        ));

        let outcome = server.join().unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }

        assert_eq!(outcome.client_cols.len(), blocks);
        assert_eq!(outcome.revealed.len(), blocks, "joiner revealed its block");
        let participants: Vec<usize> = outcome.rounds.iter().map(|r| r.participants).collect();
        assert_eq!(participants[0], blocks - 1);
        assert_eq!(*participants.last().unwrap(), blocks, "{participants:?}");
        let err = assembled_error(&problem, &partition, &outcome.revealed);
        assert!(err < 5e-3, "elastic TCP recovery err {err}");
    }

    fn spawn_resumable_worker(
        addr: String,
        problem: &RpcaProblem,
        partition: &ColumnPartition,
        id: usize,
        faults: FaultPlan,
    ) -> std::thread::JoinHandle<dcf_pca::anyhow::Result<usize>> {
        use dcf_pca::coordinator::client::run_client_resumable;
        use dcf_pca::coordinator::transport::retry::BackoffPolicy;
        use dcf_pca::coordinator::transport::Channel;

        let spec = problem.spec;
        let (a, b) = partition.range(id);
        let m_block = problem.observed.cols_range(a, b);
        let truth = (problem.l0.cols_range(a, b), problem.s0.cols_range(a, b));
        std::thread::spawn(move || {
            let cfg = ClientConfig {
                id,
                job: 0,
                n_frac: (b - a) as f64 / spec.n as f64,
                data: Box::new(m_block),
                hyper: FactorHyper::default_for(spec.m, spec.n, spec.rank),
                polish_sweeps: 3,
                truth: Some(truth),
                faults,
                compression: Compression::None,
                dp_sigma: 0.0,
            };
            let connect = || TcpChannel::connect(&addr).map(|c| Box::new(c) as Box<dyn Channel>);
            let policy = BackoffPolicy {
                base: Duration::from_millis(20),
                max: Duration::from_millis(200),
                ..Default::default()
            };
            run_client_resumable(connect, cfg, &NativeKernel::new(), &policy)
        })
    }

    /// The reconnect tentpole over real sockets: a live worker severs its
    /// TCP connection mid-round — after computing its reply, before
    /// sending it — and the resumable transport redials within the round
    /// deadline. The straggler cut must never fire, every round reduces
    /// all E updates, and U matches a fault-free run bitwise.
    #[test]
    fn tcp_worker_killed_and_restarted_mid_round_completes_without_a_cut() {
        let spec = ProblemSpec::square(60, 3, 0.05);
        let problem = spec.generate(7);
        let e = 4;
        let rounds = 30;
        let partition = ColumnPartition::even(spec.n, e);
        let mut dcf = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(rounds);
        // the grace window defaults to the round deadline: redials with a
        // 20 ms backoff land far inside 30 s
        dcf.round_timeout = Duration::from_secs(30);
        dcf.fault_policy = FaultPolicy::SkipMissing;

        let run = |flapped_worker: Option<usize>| -> ServerOutcome {
            let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
            let addr = listener.local_addr().unwrap().to_string();
            let server = run_epoll_server(listener, server_cfg_for(&problem, &dcf), e);
            let workers: Vec<_> = (0..e)
                .map(|id| {
                    let faults = if flapped_worker == Some(id) {
                        FaultPlan { disconnect_at_round: Some(6), ..Default::default() }
                    } else {
                        FaultPlan::default()
                    };
                    spawn_resumable_worker(addr.clone(), &problem, &partition, id, faults)
                })
                .collect();
            let outcome = server.join().unwrap();
            for w in workers {
                let served = w.join().unwrap().unwrap();
                assert_eq!(served, rounds, "every worker serves every round exactly once");
            }
            outcome
        };

        let reference = run(None);
        let flapped = run(Some(2));

        assert_eq!(flapped.u, reference.u, "mid-round reconnect changed U bitwise");
        let participants: Vec<usize> = flapped.rounds.iter().map(|r| r.participants).collect();
        assert!(participants.iter().all(|&p| p == e), "a reconnect cut a worker: {participants:?}");
        assert_eq!(flapped.revealed.len(), e);
        for (a, b) in reference.rounds.iter().zip(&flapped.rounds) {
            assert_eq!(a.err, b.err, "round {} err diverged", a.round);
            assert_eq!(a.mean_grad_norm, b.mean_grad_norm);
            assert_eq!(a.dispersion, b.dispersion);
        }
    }
}
