//! RoundEngine integration: the sans-I/O state machine driven purely
//! from in-memory events (no sockets, no channels, no clock), plus the
//! straggler/elasticity behavior of the reactor-driven paths.

use std::collections::VecDeque;
use std::time::Duration;

use dcf_pca::algorithms::factor::{polish_sweep, ClientState, FactorHyper};
use dcf_pca::coordinator::client::FaultPlan;
use dcf_pca::coordinator::driver::{run_dcf_pca, DcfPcaConfig};
use dcf_pca::coordinator::engine::{Action, RoundEngine};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::coordinator::protocol::{ToClient, ToServer};
use dcf_pca::coordinator::server::{FaultPolicy, ServerConfig, ServerOutcome};
use dcf_pca::coordinator::Compression;
use dcf_pca::linalg::{matmul_nt, Mat, Workspace};
use dcf_pca::rpca::partition::ColumnPartition;
use dcf_pca::rpca::problem::{ProblemSpec, RpcaProblem};
use dcf_pca::runtime::pool;

// ---------------------------------------------------------------------------
// in-memory federation: a client that is itself sans-I/O
// ---------------------------------------------------------------------------

/// Mirrors `run_client` exactly (same state/workspace/polish sequence),
/// but produces outbound messages into a queue instead of a channel —
/// so an engine test never touches a transport or a clock.
struct SimClient {
    id: u32,
    job: u32,
    m_block: Mat,
    hyper: FactorHyper,
    n_frac: f64,
    polish_sweeps: usize,
    truth: Option<(Mat, Mat)>,
    state: ClientState,
    ws: Workspace,
    kernel: NativeKernel,
    outbox: VecDeque<Vec<u8>>,
}

impl SimClient {
    fn new(
        id: usize,
        job: u32,
        m_block: Mat,
        hyper: FactorHyper,
        n_frac: f64,
        truth: Option<(Mat, Mat)>,
    ) -> Self {
        let (m, n_i) = m_block.shape();
        let mut outbox = VecDeque::new();
        outbox.push_back(
            ToServer::Hello { client: id as u32, cols: n_i as u64 }
                .encode_with(job, Compression::None),
        );
        SimClient {
            id: id as u32,
            job,
            m_block,
            hyper,
            n_frac,
            polish_sweeps: 3,
            truth,
            state: ClientState::zeros(m, n_i, hyper.rank),
            ws: Workspace::new(m, n_i, hyper.rank),
            kernel: NativeKernel::new(),
            outbox,
        }
    }

    fn handle(&mut self, bytes: &[u8]) {
        let (job, msg) = ToClient::decode_job(bytes).unwrap();
        assert_eq!(job, self.job, "client {} got a message for job {job}", self.id);
        match msg {
            ToClient::Round { round, k_local, eta, u } => {
                let mut u = u;
                let out = self
                    .kernel
                    .local_epoch(
                        &mut u,
                        &self.m_block,
                        &mut self.state,
                        &self.hyper,
                        self.n_frac,
                        eta,
                        k_local as usize,
                        &mut self.ws,
                    )
                    .unwrap();
                let err_num = match &self.truth {
                    Some((l0, s0)) => {
                        let l_i = matmul_nt(&u, &self.state.v);
                        (&l_i - l0).frob_norm_sq() + (&self.state.s - s0).frob_norm_sq()
                    }
                    None => f64::NAN,
                };
                self.outbox.push_back(
                    ToServer::Update {
                        client: self.id,
                        round,
                        u,
                        grad_norm: out.grad_norm,
                        lipschitz: out.lipschitz,
                        err_num,
                        local_secs: 0.0,
                    }
                    .encode_with(self.job, Compression::None),
                );
            }
            ToClient::Finish { reveal, final_u } => {
                for _ in 0..self.polish_sweeps {
                    polish_sweep(
                        &final_u,
                        &self.m_block,
                        &mut self.state,
                        &self.hyper,
                        pool::global(),
                        &mut self.ws,
                    )
                    .expect("polish sweep failed");
                }
                let reply = if reveal {
                    let l_i = matmul_nt(&final_u, &self.state.v);
                    ToServer::Reveal { client: self.id, l: l_i, s: self.state.s.clone() }
                } else {
                    ToServer::Withhold { client: self.id }
                };
                self.outbox
                    .push_back(reply.encode_with(self.job, Compression::None));
            }
            ToClient::Shutdown => {}
        }
    }
}

/// Feed the federation to completion. `order[k]` decides whose pending
/// messages enter the engine first after each step — i.e. the simulated
/// arrival order. `late_hello = Some((ep, after))` withholds one client's
/// Hello until `after` inbound messages have been processed (elastic
/// join mid-run).
fn drive_in_memory(
    engine: &mut RoundEngine,
    clients: &mut [SimClient],
    order: &[usize],
    late_hello: Option<(usize, usize)>,
) {
    let mut inbound: VecDeque<(usize, Vec<u8>)> = VecDeque::new();
    let late_ep = late_hello.map(|(ep, _)| ep);
    for &i in order {
        if Some(i) != late_ep {
            while let Some(m) = clients[i].outbox.pop_front() {
                inbound.push_back((i, m));
            }
        }
    }
    // a synthetic clock the engine never reads on its own
    let mut now = Duration::from_millis(1);
    let mut processed = 0usize;
    let mut joined = late_hello.is_none();
    let mut guard = 0usize;
    while !engine.all_done() {
        guard += 1;
        assert!(guard < 200_000, "engine made no progress");
        if !joined {
            if let Some((ep, after)) = late_hello {
                if processed >= after {
                    while let Some(m) = clients[ep].outbox.pop_front() {
                        inbound.push_back((ep, m));
                    }
                    joined = true;
                }
            }
        }
        let (ep, bytes) = inbound.pop_front().expect("engine idle but not done");
        processed += 1;
        now += Duration::from_millis(1);
        let actions = engine.handle_message(ep, &bytes, now);
        for a in actions {
            match a {
                Action::Send { ep, bytes } => clients[ep].handle(&bytes),
                Action::Close { .. } | Action::JobDone { .. } => {}
            }
        }
        for &i in order {
            if joined || Some(i) != late_ep {
                while let Some(m) = clients[i].outbox.pop_front() {
                    inbound.push_back((i, m));
                }
            }
        }
    }
}

/// Driver-equivalent ServerConfig for a generated problem.
fn server_cfg_for(problem: &RpcaProblem, cfg: &DcfPcaConfig) -> ServerConfig {
    let mut s = ServerConfig::new(problem.spec.m, cfg.hyper.rank, cfg.rounds, cfg.k_local);
    s.schedule = cfg.schedule;
    s.aggregation = cfg.aggregation;
    s.privacy = cfg.privacy.clone();
    s.seed = cfg.seed;
    s.round_timeout = cfg.round_timeout;
    s.fault_policy = cfg.fault_policy;
    s.err_denominator = Some(problem.l0.frob_norm_sq() + problem.s0.frob_norm_sq());
    s.compression = cfg.compression;
    s.participation = cfg.participation;
    s
}

fn sim_clients(problem: &RpcaProblem, cfg: &DcfPcaConfig, e: usize, job: u32) -> Vec<SimClient> {
    let n = problem.spec.n;
    let partition = ColumnPartition::even(n, e);
    (0..e)
        .map(|i| {
            let (a, b) = partition.range(i);
            SimClient::new(
                i,
                job,
                problem.observed.cols_range(a, b),
                cfg.hyper,
                (b - a) as f64 / n as f64,
                Some((problem.l0.cols_range(a, b), problem.s0.cols_range(a, b))),
            )
        })
        .collect()
}

/// Eq. 30 error over revealed blocks (post-polish), as the driver
/// assembles it.
fn assembled_error(
    problem: &RpcaProblem,
    partition: &ColumnPartition,
    revealed: &[(usize, Mat, Mat)],
) -> f64 {
    let mut num = 0.0;
    let mut den = 0.0;
    for (i, l_i, s_i) in revealed {
        let (a, b) = partition.range(*i);
        let l0 = problem.l0.cols_range(a, b);
        let s0 = problem.s0.cols_range(a, b);
        num += (l_i - &l0).frob_norm_sq() + (s_i - &s0).frob_norm_sq();
        den += l0.frob_norm_sq() + s0.frob_norm_sq();
    }
    num / den
}

// ---------------------------------------------------------------------------
// sans-I/O: full E=4 federation from in-memory events only
// ---------------------------------------------------------------------------

#[test]
fn engine_runs_e4_purely_in_memory_and_matches_driver_bitwise() {
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(7);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(4).with_rounds(40);

    // reference: the threaded in-proc driver (ChannelReactor path)
    let reference = run_dcf_pca(&problem, &cfg).unwrap();
    assert!(reference.final_error.unwrap() < 1e-3);

    // same federation, zero I/O: every event is an in-memory Vec<u8>
    let mut engine = RoundEngine::new();
    engine.add_job(0, server_cfg_for(&problem, &cfg), 4);
    let mut clients = sim_clients(&problem, &cfg, 4, 0);
    drive_in_memory(&mut engine, &mut clients, &[0, 1, 2, 3], None);
    let outcome: ServerOutcome = engine.take_result(0).unwrap().unwrap();

    assert_eq!(outcome.u, reference.u, "sans-I/O engine diverged from the driver");
    assert_eq!(outcome.rounds.len(), 40);
    assert!(outcome.rounds.last().unwrap().err.unwrap() < 1e-3);
    assert_eq!(outcome.revealed.len(), 4);
    assert_eq!(outcome.client_cols, vec![15; 4]);
}

#[test]
fn engine_aggregate_is_bitwise_invariant_to_arrival_order() {
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(9);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(4).with_rounds(12);

    let mut results = Vec::new();
    for order in [[0, 1, 2, 3], [3, 1, 0, 2], [2, 3, 1, 0]] {
        let mut engine = RoundEngine::new();
        engine.add_job(0, server_cfg_for(&problem, &cfg), 4);
        let mut clients = sim_clients(&problem, &cfg, 4, 0);
        drive_in_memory(&mut engine, &mut clients, &order, None);
        results.push(engine.take_result(0).unwrap().unwrap());
    }
    // slot-ordered reduction ⇒ same U and same telemetry sums, bitwise,
    // no matter which client's update lands first
    assert_eq!(results[0].u, results[1].u);
    assert_eq!(results[0].u, results[2].u);
    for k in 1..results.len() {
        for (a, b) in results[0].rounds.iter().zip(&results[k].rounds) {
            assert_eq!(a.err, b.err);
            assert_eq!(a.mean_grad_norm, b.mean_grad_norm);
            assert_eq!(a.dispersion, b.dispersion);
        }
    }
}

#[test]
fn engine_elastic_join_enters_at_next_round_boundary() {
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(7);
    let cfg = DcfPcaConfig::default_for(&spec).with_clients(5).with_rounds(40);

    let mut engine = RoundEngine::new();
    // only 4 founding members; the 5th Hello arrives mid-run
    engine.add_job(0, server_cfg_for(&problem, &cfg), 4);
    let mut clients = sim_clients(&problem, &cfg, 5, 0);
    // 4 hellos + 3 rounds × 4 updates = 16 messages, then client 4 knocks
    drive_in_memory(&mut engine, &mut clients, &[0, 1, 2, 3, 4], Some((4, 16)));
    let outcome = engine.take_result(0).unwrap().unwrap();

    assert_eq!(outcome.client_cols.len(), 5, "late joiner registered");
    assert_eq!(outcome.revealed.len(), 5, "late joiner revealed its block");
    let participants: Vec<usize> = outcome.rounds.iter().map(|r| r.participants).collect();
    assert_eq!(participants[0], 4, "founding rounds run with 4 clients");
    assert_eq!(*participants.last().unwrap(), 5, "joiner active after the boundary");
    assert!(participants.windows(2).all(|w| w[0] <= w[1]), "{participants:?}");
    // recovery still lands: U saw all blocks for most of the run, and
    // polish refits every revealed block against the final U
    let partition = ColumnPartition::even(spec.n, 5);
    let err = assembled_error(&problem, &partition, &outcome.revealed);
    assert!(err < 5e-3, "elastic-join recovery err {err}");
}

#[test]
fn engine_multiplexes_concurrent_jobs_over_one_reactor() {
    use dcf_pca::coordinator::client::{run_client, ClientConfig};
    use dcf_pca::coordinator::transport::inproc::pair;
    use dcf_pca::coordinator::transport::reactor::{drive, ChannelReactor};
    use dcf_pca::coordinator::transport::Channel;

    let spec_a = ProblemSpec::square(50, 2, 0.05);
    let spec_b = ProblemSpec::square(40, 3, 0.05);
    let problem_a = spec_a.generate(21);
    let problem_b = spec_b.generate(22);
    let cfg_a = DcfPcaConfig::default_for(&spec_a).with_clients(3).with_rounds(25).with_seed(0xA);
    let cfg_b = DcfPcaConfig::default_for(&spec_b).with_clients(3).with_rounds(30).with_seed(0xB);

    // single-job references
    let ref_a = run_dcf_pca(&problem_a, &cfg_a).unwrap();
    let ref_b = run_dcf_pca(&problem_b, &cfg_b).unwrap();

    // one coordinator, one reactor, six endpoints, two interleaved jobs
    let mut channels: Vec<Box<dyn Channel>> = Vec::new();
    let mut handles = Vec::new();
    for ep in 0..6 {
        let job = (ep % 2) as u32;
        let id = ep / 2;
        let (problem, cfg) = if job == 0 { (&problem_a, &cfg_a) } else { (&problem_b, &cfg_b) };
        let n = problem.spec.n;
        let partition = ColumnPartition::even(n, 3);
        let (a, b) = partition.range(id);
        let client_cfg = ClientConfig {
            id,
            job,
            data: Box::new(problem.observed.cols_range(a, b)),
            hyper: cfg.hyper,
            n_frac: (b - a) as f64 / n as f64,
            polish_sweeps: cfg.polish_sweeps,
            truth: Some((problem.l0.cols_range(a, b), problem.s0.cols_range(a, b))),
            faults: FaultPlan::default(),
            compression: Compression::None,
            dp_sigma: 0.0,
        };
        let (server_side, mut client_side) = pair();
        channels.push(Box::new(server_side));
        handles.push(std::thread::spawn(move || {
            run_client(&mut client_side, client_cfg, &NativeKernel::new())
        }));
    }

    let mut engine = RoundEngine::new();
    engine.add_job(0, server_cfg_for(&problem_a, &cfg_a), 3);
    engine.add_job(1, server_cfg_for(&problem_b, &cfg_b), 3);
    let mut reactor = ChannelReactor::new(&mut channels);
    drive(&mut reactor, &mut engine).unwrap();
    let out_a = engine.take_result(0).unwrap().unwrap();
    let out_b = engine.take_result(1).unwrap().unwrap();
    for h in handles {
        h.join().unwrap().unwrap();
    }

    // each multiplexed job matches its solo run bitwise
    assert_eq!(out_a.u, ref_a.u);
    assert_eq!(out_b.u, ref_b.u);
    assert_eq!(out_a.rounds.len(), 25);
    assert_eq!(out_b.rounds.len(), 30);
    assert!(out_a.rounds.last().unwrap().err.unwrap() < 5e-2);
    assert!(out_b.rounds.last().unwrap().err.unwrap() < 5e-2);
}

// ---------------------------------------------------------------------------
// stragglers over the real in-proc transport (driver path)
// ---------------------------------------------------------------------------

#[test]
fn straggler_round_time_tracks_max_not_sum() {
    let spec = ProblemSpec::square(64, 2, 0.05);
    let problem = spec.generate(31);
    let e = 8;
    let delay = Duration::from_millis(60);
    let mut cfg = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(4);
    cfg.faults = vec![FaultPlan { reply_delay: Some(delay), ..Default::default() }; e];
    let res = run_dcf_pca(&problem, &cfg).unwrap();

    let mean_round = res.rounds.iter().map(|r| r.round_secs).sum::<f64>() / res.rounds.len() as f64;
    let sum_of_delays = e as f64 * delay.as_secs_f64(); // 0.48 s
    assert!(
        mean_round < 0.5 * sum_of_delays,
        "round time {mean_round:.3}s looks sequential (sum would be {sum_of_delays:.2}s)"
    );
    assert!(
        mean_round >= delay.as_secs_f64() * 0.9,
        "round time {mean_round:.3}s beat the slowest client — impossible"
    );
}

#[test]
fn deterministic_u_regardless_of_which_client_straggles() {
    let spec = ProblemSpec::square(50, 2, 0.05);
    let problem = spec.generate(32);
    let e = 5;
    let base = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(6);

    let mut slow_first = base.clone();
    slow_first.faults = vec![FaultPlan::default(); e];
    slow_first.faults[0].reply_delay = Some(Duration::from_millis(40));

    let mut slow_last = base.clone();
    slow_last.faults = vec![FaultPlan::default(); e];
    slow_last.faults[e - 1].reply_delay = Some(Duration::from_millis(40));

    let a = run_dcf_pca(&problem, &slow_first).unwrap();
    let b = run_dcf_pca(&problem, &slow_last).unwrap();
    let c = run_dcf_pca(&problem, &base).unwrap();
    // arrival order changed; slot-ordered reduction keeps U (and hence
    // L, S) bitwise identical
    assert_eq!(a.u, b.u);
    assert_eq!(a.u, c.u);
    assert_eq!(a.l, b.l);
    assert_eq!(a.s, b.s);
}

#[test]
fn straggler_cut_bounds_round_latency() {
    let spec = ProblemSpec::square(64, 2, 0.05);
    let problem = spec.generate(33);
    let e = 8;
    let deadline = Duration::from_millis(150);
    let delay = Duration::from_millis(400);

    // baseline: no straggler, same deadline
    let mut base = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(6);
    base.fault_policy = FaultPolicy::SkipMissing;
    base.round_timeout = deadline;
    let baseline = run_dcf_pca(&problem, &base).unwrap();
    let base_mean =
        baseline.rounds.iter().map(|r| r.round_secs).sum::<f64>() / baseline.rounds.len() as f64;

    // one client 200 ms late every round: the cut closes each round at
    // the deadline instead of waiting out the straggler
    let mut cfg = base.clone();
    cfg.faults = vec![FaultPlan::default(); e];
    cfg.faults[0].reply_delay = Some(delay);
    let res = run_dcf_pca(&problem, &cfg).unwrap();

    let mean_round = res.rounds.iter().map(|r| r.round_secs).sum::<f64>() / res.rounds.len() as f64;
    assert!(
        mean_round < base_mean + 2.0 * deadline.as_secs_f64(),
        "straggler dominated the round: {mean_round:.3}s vs baseline {base_mean:.3}s"
    );
    assert!(
        mean_round < delay.as_secs_f64(),
        "round waited out the straggler: {mean_round:.3}s"
    );
    // the cut excluded the straggler, not the run: it overshoots every
    // deadline so it can never be a participant, while the healthy
    // majority lands (≤ rather than == tolerates scheduler noise)
    let participants: Vec<usize> = res.rounds.iter().map(|r| r.participants).collect();
    assert!(participants.iter().all(|&p| p <= e - 1), "{participants:?}");
    assert!(participants.iter().any(|&p| p == e - 1), "{participants:?}");
    // hundreds of ms behind per round, it also misses the reveal
    // deadline; the healthy majority reveals
    assert!(res.withheld_clients.contains(&0));
    assert!(res.revealed_clients.len() >= e - 2);
    assert!(!res.revealed_clients.contains(&0));
}

// ---------------------------------------------------------------------------
// reveal-phase faults (regression: used to abort the whole run)
// ---------------------------------------------------------------------------

#[test]
fn reveal_phase_crash_is_withheld_under_skip_missing() {
    let spec = ProblemSpec::square(40, 2, 0.05);
    let problem = spec.generate(34);
    let mut cfg = DcfPcaConfig::default_for(&spec).with_clients(3).with_rounds(12);
    cfg.fault_policy = FaultPolicy::SkipMissing;
    cfg.round_timeout = Duration::from_secs(5);
    cfg.faults = vec![
        FaultPlan::default(),
        FaultPlan { crash_at_finish: true, ..Default::default() },
        FaultPlan::default(),
    ];
    let res = run_dcf_pca(&problem, &cfg).unwrap();
    // every round ran with all three; only the reveal is missing
    assert!(res.rounds.iter().all(|r| r.participants == 3));
    assert_eq!(res.withheld_clients, vec![1]);
    assert_eq!(res.revealed_clients, vec![0, 2]);
    assert!(res.final_error.unwrap() < 5e-2);
}

#[test]
fn reveal_phase_crash_still_fails_under_strict() {
    let spec = ProblemSpec::square(30, 2, 0.05);
    let problem = spec.generate(35);
    let mut cfg = DcfPcaConfig::default_for(&spec).with_clients(2).with_rounds(5);
    cfg.fault_policy = FaultPolicy::Strict;
    cfg.round_timeout = Duration::from_secs(2);
    cfg.faults = vec![
        FaultPlan { crash_at_finish: true, ..Default::default() },
        FaultPlan::default(),
    ];
    assert!(run_dcf_pca(&problem, &cfg).is_err());
}

// ---------------------------------------------------------------------------
// epoll reactor end-to-end (Linux)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod epoll_e2e {
    use super::*;
    use dcf_pca::coordinator::client::{run_client, ClientConfig};
    use dcf_pca::coordinator::transport::reactor::{drive, EpollReactor};
    use dcf_pca::coordinator::transport::tcp::TcpChannel;

    fn spawn_worker(
        addr: String,
        problem: &RpcaProblem,
        partition: &ColumnPartition,
        id: usize,
        faults: FaultPlan,
    ) -> std::thread::JoinHandle<dcf_pca::anyhow::Result<usize>> {
        let spec = problem.spec;
        let (a, b) = partition.range(id);
        let m_block = problem.observed.cols_range(a, b);
        let truth = (problem.l0.cols_range(a, b), problem.s0.cols_range(a, b));
        std::thread::spawn(move || {
            let mut ch = TcpChannel::connect(&addr)?;
            let cfg = ClientConfig {
                id,
                job: 0,
                n_frac: (b - a) as f64 / spec.n as f64,
                data: Box::new(m_block),
                hyper: FactorHyper::default_for(spec.m, spec.n, spec.rank),
                polish_sweeps: 3,
                truth: Some(truth),
                faults,
                compression: Compression::None,
                dp_sigma: 0.0,
            };
            run_client(&mut ch, cfg, &NativeKernel::new())
        })
    }

    fn run_epoll_server(
        listener: std::net::TcpListener,
        cfg: ServerConfig,
        expected: usize,
    ) -> std::thread::JoinHandle<ServerOutcome> {
        std::thread::spawn(move || {
            let mut engine = RoundEngine::new();
            engine.add_job(0, cfg, expected);
            let mut reactor = EpollReactor::new(listener).unwrap();
            drive(&mut reactor, &mut engine).unwrap();
            engine.take_result(0).unwrap().unwrap()
        })
    }

    /// Mirrors `driver::tests::recovers_distributed_small` numerically —
    /// same problem, seed, E, rounds — so the epoll reactor must land the
    /// same sub-1e-3 recovery as the in-proc path.
    #[test]
    fn epoll_reactor_recovers_like_the_inproc_path() {
        let spec = ProblemSpec::square(60, 3, 0.05);
        let problem = spec.generate(7);
        let e = 5;
        let partition = ColumnPartition::even(spec.n, e);
        let dcf = DcfPcaConfig::default_for(&spec).with_clients(e).with_rounds(40);
        let cfg = server_cfg_for(&problem, &dcf);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = run_epoll_server(listener, cfg, e);
        let workers: Vec<_> = (0..e)
            .map(|id| spawn_worker(addr.clone(), &problem, &partition, id, FaultPlan::default()))
            .collect();

        let outcome = server.join().unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }
        assert_eq!(outcome.revealed.len(), e);
        let err = assembled_error(&problem, &partition, &outcome.revealed);
        assert!(err < 1e-3, "epoll recovery err {err}");
    }

    #[test]
    fn epoll_reactor_accepts_late_joiner_mid_run() {
        let spec = ProblemSpec::square(60, 3, 0.05);
        let problem = spec.generate(11);
        let blocks = 5; // 4 founding workers + 1 elastic joiner
        let partition = ColumnPartition::even(spec.n, blocks);
        let mut dcf = DcfPcaConfig::default_for(&spec).with_clients(blocks).with_rounds(40);
        dcf.round_timeout = Duration::from_secs(30);
        let cfg = server_cfg_for(&problem, &dcf);

        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = run_epoll_server(listener, cfg, blocks - 1);

        // founding workers pace the run at ≥20 ms per round so the
        // joiner reliably lands mid-training
        let pace = FaultPlan { reply_delay: Some(Duration::from_millis(20)), ..Default::default() };
        let mut workers: Vec<_> = (0..blocks - 1)
            .map(|id| spawn_worker(addr.clone(), &problem, &partition, id, pace))
            .collect();
        std::thread::sleep(Duration::from_millis(250));
        workers.push(spawn_worker(
            addr.clone(),
            &problem,
            &partition,
            blocks - 1,
            FaultPlan::default(),
        ));

        let outcome = server.join().unwrap();
        for w in workers {
            w.join().unwrap().unwrap();
        }

        assert_eq!(outcome.client_cols.len(), blocks);
        assert_eq!(outcome.revealed.len(), blocks, "joiner revealed its block");
        let participants: Vec<usize> = outcome.rounds.iter().map(|r| r.participants).collect();
        assert_eq!(participants[0], blocks - 1);
        assert_eq!(*participants.last().unwrap(), blocks, "{participants:?}");
        let err = assembled_error(&problem, &partition, &outcome.revealed);
        assert!(err < 5e-3, "elastic TCP recovery err {err}");
    }
}
