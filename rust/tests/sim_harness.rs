//! Simulation-harness integration: the `sim_smoke` subset runs inside
//! the tier-1 `cargo test -q` budget; the exhaustive fuzz sweeps are
//! `#[ignore]`d (CI's `sim-fuzz` and `reconnect-fuzz` jobs run
//! `dcf-pca simulate --seeds 0..256` — plain and `--flaky` — on the
//! release binary instead: same code path, faster).

use std::time::{Duration, Instant};

use dcf_pca::coordinator::driver::{run_dcf_pca, DcfPcaConfig};
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::sim::{Dir, Fault, FaultSchedule, SimConfig, SimHarness};

fn harness() -> SimHarness {
    SimHarness::new(SimConfig::default()).expect("default sim config must converge")
}

fn default_schedule() -> FaultSchedule {
    let cfg = SimConfig::default();
    FaultSchedule::fault_free(0, cfg.clients, cfg.rounds)
}

// ---------------------------------------------------------------------------
// sim_smoke: fast subset, tier-1
// ---------------------------------------------------------------------------

/// Acceptance: the fault-free simulated federation is bitwise-identical
/// (U factor) to the threaded in-proc driver at the same seed/shape.
#[test]
fn sim_smoke_fault_free_matches_inproc_driver_bitwise() {
    let h = harness();
    let cfg = h.config().clone();
    let spec = ProblemSpec::square(cfg.n, cfg.rank, cfg.sparsity);
    let problem = spec.generate(cfg.problem_seed);
    let driver_cfg = DcfPcaConfig::default_for(&spec)
        .with_clients(cfg.clients)
        .with_rounds(cfg.rounds)
        .with_k_local(cfg.k_local)
        .with_seed(cfg.server_seed);
    let reference = run_dcf_pca(&problem, &driver_cfg).unwrap();
    assert_eq!(
        h.reference().u,
        reference.u,
        "virtual-time simulation diverged from the threaded driver"
    );
    assert_eq!(h.reference().rounds.len(), reference.rounds.len());
    for (a, b) in h.reference().rounds.iter().zip(&reference.rounds) {
        assert_eq!(a.err, b.err, "round {} err diverged", a.round);
        assert_eq!(a.participants, b.participants);
    }
}

/// SimNet really is a drop-in Reactor: the production `drive` loop runs
/// the whole federation over it, in virtual time, to the same U.
#[test]
fn sim_smoke_production_drive_loop_runs_over_simnet() {
    let h = harness();
    let outcome = h.run_production_drive(&default_schedule()).unwrap();
    assert_eq!(outcome.u, h.reference().u);
    assert_eq!(outcome.revealed.len(), h.config().clients);
}

/// A small seed sweep holds every invariant and runs in virtual time
/// (simulated duration visible, negligible wall time per seed).
#[test]
fn sim_smoke_seed_sweep_holds_invariants() {
    let h = harness();
    let wall = Instant::now();
    let summary = h.fuzz(0..12);
    assert_eq!(summary.seeds_run, 12);
    assert!(
        summary.failures.is_empty(),
        "seed sweep violated invariants: {}",
        summary
            .failures
            .iter()
            .map(|v| v.to_string())
            .collect::<Vec<_>>()
            .join("\n---\n")
    );
    // the drawn worlds are not all trivial
    assert!(summary.reports.iter().any(|r| r.faults > 0), "no faults drawn in 12 seeds");
    assert!(summary.virtual_total > Duration::ZERO);
    assert!(wall.elapsed() < Duration::from_secs(120), "sim is sleeping on the wall clock");
}

/// A calm seed (latency jitter only) must reproduce the fault-free run
/// bit for bit — the slot-ordered-reduction invariant, end to end.
#[test]
fn sim_smoke_calm_seed_is_bitwise_clean() {
    let h = harness();
    let cfg = h.config();
    let calm_seed = (0u64..)
        .find(|&s| FaultSchedule::draw(s, cfg.clients, cfg.rounds).is_fault_free())
        .expect("a fifth of seeds draw calm worlds");
    let report = h.check_seed(calm_seed).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.bitwise_clean, "calm seed {calm_seed} did not verify bitwise");
    assert_eq!(report.rounds_run, cfg.rounds);
    assert_eq!(report.min_participants, cfg.clients);
}

/// Reveal-phase crash (the PR-3 withheld-reveal regression): the run
/// completes, the dead client is withheld, everyone else reveals.
#[test]
fn sim_smoke_reveal_phase_crash_is_withheld() {
    let h = harness();
    let rounds = h.config().rounds;
    let mut schedule = default_schedule();
    // upstream message rounds+1 is the finish reply when every round ran
    schedule.faults.push(Fault::CrashBeforeSend { client: 1, nth: rounds + 1 });
    let report = h.check_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok, "reveal-phase crash must not abort the job");
    assert_eq!(report.rounds_run, rounds, "crash was after the last round");
    assert_eq!(report.min_participants, h.config().clients, "every round was full");
    assert!(!report.bitwise_clean, "a materialized crash is not a clean run");
}

/// One dropped round update = one straggler cut, then full recovery.
#[test]
fn sim_smoke_dropped_update_cuts_exactly_one_round() {
    let h = harness();
    let mut schedule = default_schedule();
    schedule.faults.push(Fault::Drop { dir: Dir::Up, client: 2, nth: 3 });
    assert!(schedule.under_budget(h.config().round_timeout));
    let report = h.check_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok);
    assert_eq!(report.rounds_run, h.config().rounds);
    assert_eq!(report.min_participants, h.config().clients - 1, "one cut round");
    // under budget ⇒ the tolerance invariant already ran inside check
    assert!(report.final_err.unwrap() <= h.config().err_tolerance);
}

/// Membership chaos — a late joiner plus a partition window — still
/// terminates cleanly with every invariant satisfied.
#[test]
fn sim_smoke_late_join_and_partition_terminate() {
    let h = harness();
    let mut schedule = default_schedule();
    schedule.faults.push(Fault::LateJoin { client: 3, at_ms: 20 });
    schedule.faults.push(Fault::Partition { client: 1, from_ms: 10, until_ms: 60 });
    let report = h.check_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok, "healthy clients remained — the job must finish");
    assert!(report.materialized > 0, "the join (at least) must have materialized");
}

/// A recoverable link flap — down and redialed within the round
/// deadline — must be invisible: no straggler cut, full participation,
/// and U bitwise-identical to the fault-free run (invariant 6).
#[test]
fn sim_smoke_recoverable_flap_is_bitwise_invisible() {
    let h = harness();
    let mut schedule = default_schedule();
    schedule.faults.push(Fault::Disconnect { client: 1, at_ms: 25, reconnect_after_ms: 5 });
    assert!(schedule.under_budget(h.config().round_timeout), "flap must be recoverable");
    let report = h.check_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok);
    assert_eq!(report.rounds_run, h.config().rounds);
    assert_eq!(report.min_participants, h.config().clients, "the flap cut a round");
    assert!(report.bitwise_clean, "resume changed the reduction");
    assert!(report.materialized > 0, "the link drop must have materialized");
}

/// A flap that outlives the grace window degrades to the pre-resume
/// departure semantics: the straggler cut adjudicates the loss, the
/// survivors finish, and the returning client rejoins at a boundary.
#[test]
fn sim_smoke_grace_expired_flap_departs_then_rejoins() {
    let h = harness();
    let mut schedule = default_schedule();
    schedule.faults.push(Fault::Disconnect { client: 1, at_ms: 25, reconnect_after_ms: 60 });
    assert!(
        !schedule.under_budget(h.config().round_timeout),
        "a flap longer than the deadline is not recoverable"
    );
    let report = h.check_schedule(&schedule).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.completed_ok, "healthy clients remained — the job must finish");
    assert_eq!(report.rounds_run, h.config().rounds);
    assert_eq!(report.min_participants, h.config().clients - 1, "exactly one client was cut");
    assert!(!report.bitwise_clean, "a departure is not bitwise-invisible");
}

/// The flap-heavy distribution (`--flaky`) holds every invariant over a
/// small sweep, and a recoverable-flaps-only world from it verifies
/// bitwise end to end.
#[test]
fn sim_smoke_flaky_distribution_sweep_holds_invariants() {
    let h = harness();
    let cfg = h.config().clone();
    let mut faulty_worlds = 0usize;
    for seed in 0..12 {
        let report = h.check_seed_flaky(seed).unwrap_or_else(|v| panic!("{v}"));
        if report.faults > 0 {
            faulty_worlds += 1;
        }
    }
    assert!(faulty_worlds > 0, "no flaps drawn in 12 flaky seeds");

    let flap_seed = (0u64..)
        .find(|&s| {
            let sched = FaultSchedule::draw_flaky(s, cfg.clients, cfg.rounds);
            !sched.faults.is_empty() && sched.under_budget(cfg.round_timeout)
        })
        .expect("most flaky worlds draw short, recoverable flaps");
    let report = h.check_seed_flaky(flap_seed).unwrap_or_else(|v| panic!("{v}"));
    assert!(report.bitwise_clean, "recoverable flap world {flap_seed} did not verify bitwise");
    assert_eq!(report.min_participants, cfg.clients);
}

/// Shrink mechanics: a passing schedule yields no shrink; a failing one
/// is greedily minimized until only failure-relevant state remains.
#[test]
fn sim_smoke_shrink_minimizes_failing_schedules() {
    let h = harness();
    assert!(h.shrink(&default_schedule()).is_none(), "passing schedules do not shrink");

    // a schedule sized for the wrong fleet fails deterministically no
    // matter which fault events it carries — shrink must strip all the
    // decoy faults and still reproduce the failure
    let cfg = SimConfig::default();
    let mut bad = FaultSchedule::fault_free(99, cfg.clients - 1, cfg.rounds);
    bad.faults.push(Fault::Drop { dir: Dir::Up, client: 0, nth: 1 });
    bad.faults.push(Fault::Delay { dir: Dir::Down, client: 1, nth: 2, extra_ms: 5 });
    bad.faults.push(Fault::Duplicate { dir: Dir::Up, client: 2, nth: 3 });
    let (minimal, violation) = h.shrink(&bad).expect("mis-sized schedule must keep failing");
    assert!(minimal.faults.is_empty(), "decoy faults survived shrinking: {:?}", minimal.faults);
    assert!(violation.detail.contains("sized for"), "unexpected violation: {}", violation.detail);
}

// ---------------------------------------------------------------------------
// the long sweep — explicitly opted into (CI sim-fuzz runs the CLI
// equivalent `dcf-pca simulate --seeds 0..256` on the release binary)
// ---------------------------------------------------------------------------

#[test]
#[ignore = "long fuzz sweep; run with --ignored or via `dcf-pca simulate --seeds 0..256`"]
fn sim_fuzz_seeds_0_256() {
    let h = harness();
    let summary = h.fuzz(0..256);
    assert_eq!(summary.seeds_run, 256);
    assert!(
        summary.failures.is_empty(),
        "{} of 256 seeds violated invariants; first:\n{}",
        summary.failures.len(),
        summary.failures[0]
    );
    // coverage sanity over the big sweep: calm worlds verified bitwise,
    // and some worlds actually lost updates
    assert!(summary.reports.iter().filter(|r| r.bitwise_clean).count() > 10);
    assert!(summary
        .reports
        .iter()
        .any(|r| r.completed_ok && r.min_participants < SimConfig::default().clients));
}
