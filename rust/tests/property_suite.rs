//! Cross-module property tests using the in-crate mini-proptest
//! framework (`dcf_pca::testing`). Each property runs dozens of seeded
//! random cases; failures report the case index and a replay seed.

use dcf_pca::algorithms::factor::{
    inner_objective, inner_sweep, oracle, u_gradient_into, ClientState, FactorHyper,
};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::linalg::Workspace;
use dcf_pca::runtime::pool;
use dcf_pca::coordinator::aggregate::{aggregate, Aggregation};
use dcf_pca::coordinator::compress::{put_mat_compressed, read_mat_compressed, Compression};
use dcf_pca::coordinator::privacy::{gaussian_sigma, perturb_update};
use dcf_pca::coordinator::protocol::{ToClient, ToServer};
use dcf_pca::coordinator::transport::framing::{frame_into, put_mat, FrameDecoder, Reader};
use dcf_pca::linalg::{
    matmul, matmul_nt, matmul_tn, shrink, singular_values, svd_jacobi, Mat,
};
use dcf_pca::rpca::partition::ColumnPartition;
use dcf_pca::testing::property;

#[test]
fn prop_partition_split_assemble_roundtrip() {
    property("partition roundtrip", 40, |g| {
        let rows = g.usize_in(1, 12);
        let cols = g.usize_in(2, 40);
        let clients = g.usize_in(1, cols.min(8));
        let m = g.mat(rows, cols);
        let p = if g.bool() {
            ColumnPartition::even(cols, clients)
        } else {
            let mut rng = g.rng(1);
            ColumnPartition::random_uneven(cols, clients, &mut rng)
        };
        let back = p.assemble(&p.split(&m));
        assert_eq!(m, back);
    });
}

#[test]
fn prop_mat_framing_roundtrip() {
    property("matrix framing roundtrip", 50, |g| {
        let rows = g.usize_in(1, 20);
        let cols2 = g.usize_in(1, 20);
        let m = g.mat(rows, cols2);
        let mut buf = Vec::new();
        put_mat(&mut buf, &m);
        let mut r = Reader::new(&buf);
        assert_eq!(r.mat().unwrap(), m);
        r.expect_end().unwrap();
    });
}

#[test]
fn prop_protocol_roundtrip_fuzzed() {
    property("protocol roundtrip", 50, |g| {
        let ur = g.usize_in(1, 10);
        let uc = g.usize_in(1, 5);
        let u = g.mat(ur, uc);
        let msg = ToClient::Round {
            round: g.usize_in(0, 1000) as u32,
            k_local: g.usize_in(1, 16) as u32,
            eta: g.f64_in(1e-6, 1.0),
            u: u.clone(),
        };
        assert_eq!(ToClient::decode(&msg.encode()).unwrap(), msg);
        let up = ToServer::Update {
            client: g.usize_in(0, 64) as u32,
            round: g.usize_in(0, 1000) as u32,
            u,
            count: g.usize_in(1, 256) as u32,
            cols: g.usize_in(1, 4096) as u64,
            grad_sum: g.f64_in(0.0, 1e6),
            lip_max: g.f64_in(0.0, 1e6),
            err_num_sum: g.f64_in(0.0, 1e6),
            secs_max: g.f64_in(0.0, 100.0),
            secs_sum: g.f64_in(0.0, 100.0),
        };
        assert_eq!(ToServer::decode(&up.encode()).unwrap(), up);
    });
}

#[test]
fn prop_truncated_frames_never_panic() {
    property("truncated frames rejected", 60, |g| {
        let ur = g.usize_in(1, 8);
        let uc = g.usize_in(1, 8);
        let u = g.mat(ur, uc);
        let full = ToClient::Round { round: 1, k_local: 1, eta: 0.1, u }.encode();
        let cut = g.usize_in(0, full.len().saturating_sub(1));
        // must error, not panic
        assert!(ToClient::decode(&full[..cut]).is_err());
    });
}

/// Reference one-shot framing: the historical blocking read path
/// (u32 LE length, then exactly that many payload bytes).
fn one_shot_frames(mut stream: &[u8]) -> Result<Vec<Vec<u8>>, ()> {
    let mut out = Vec::new();
    while stream.len() >= 4 {
        let len = u32::from_le_bytes(stream[..4].try_into().unwrap());
        if len > (1 << 30) {
            return Err(()); // corrupt header kills the connection
        }
        let len = len as usize;
        if stream.len() < 4 + len {
            break; // trailing partial frame: not yet arrived
        }
        out.push(stream[4..4 + len].to_vec());
        stream = &stream[4 + len..];
    }
    Ok(out)
}

/// Run the incremental decoder over `stream` split at `cuts` (sorted
/// fragment boundaries); `Err` mirrors a poisoned stream.
fn incremental_frames(stream: &[u8], cuts: &[usize]) -> Result<Vec<Vec<u8>>, ()> {
    let mut dec = FrameDecoder::new();
    let mut out = Vec::new();
    let mut prev = 0;
    for &c in cuts.iter().chain(std::iter::once(&stream.len())) {
        dec.push(&stream[prev..c]);
        prev = c;
        loop {
            match dec.next_frame() {
                Ok(Some(f)) => out.push(f),
                Ok(None) => break,
                Err(_) => return Err(()),
            }
        }
    }
    Ok(out)
}

#[test]
fn prop_frame_decoder_split_invariant() {
    // any fragmentation of a valid multi-frame stream — including one
    // byte at a time and every single split point — decodes identically
    // to the one-shot path
    property("frame decoder split invariance", 40, |g| {
        let mut stream = Vec::new();
        let mut frames = Vec::new();
        for _ in 0..g.usize_in(0, 5) {
            let len = g.usize_in(0, 60);
            let frame: Vec<u8> = (0..len).map(|_| g.usize_in(0, 255) as u8).collect();
            frame_into(&mut stream, &frame);
            frames.push(frame);
        }
        // maybe leave a dangling partial frame at the end
        if g.bool() {
            stream.extend_from_slice(&8u32.to_le_bytes());
            stream.extend_from_slice(&[1, 2, 3]); // 3 of 8 payload bytes
        }
        let reference = one_shot_frames(&stream).unwrap();
        assert_eq!(reference, frames);

        // byte at a time
        let every_byte: Vec<usize> = (1..stream.len()).collect();
        assert_eq!(incremental_frames(&stream, &every_byte).unwrap(), frames);
        // split at every single boundary in turn
        for cut in 0..=stream.len() {
            assert_eq!(incremental_frames(&stream, &[cut]).unwrap(), frames, "cut {cut}");
        }
        // a random handful of cuts
        let mut cuts: Vec<usize> =
            (0..g.usize_in(0, 6)).map(|_| g.usize_in(0, stream.len())).collect();
        cuts.sort_unstable();
        assert_eq!(incremental_frames(&stream, &cuts).unwrap(), frames);
    });
}

#[test]
fn prop_frame_decoder_garbage_prefix_matches_one_shot() {
    // a stream whose first "length" word is garbage must be rejected by
    // both paths the same way, at any fragmentation
    property("frame decoder garbage prefix", 40, |g| {
        let mut stream: Vec<u8> =
            ((1u32 << 30) + 1 + g.usize_in(0, 1 << 20) as u32).to_le_bytes().to_vec();
        for _ in 0..g.usize_in(0, 40) {
            stream.push(g.usize_in(0, 255) as u8);
        }
        assert!(one_shot_frames(&stream).is_err());
        let every_byte: Vec<usize> = (1..stream.len()).collect();
        assert!(incremental_frames(&stream, &every_byte).is_err());
        assert!(incremental_frames(&stream, &[]).is_err());
    });
}

fn compress_roundtrip(m: &Mat, codec: Compression) -> Mat {
    let mut buf = Vec::new();
    put_mat_compressed(&mut buf, m, codec);
    let mut r = Reader::new(&buf);
    let out = read_mat_compressed(&mut r).unwrap();
    r.expect_end().unwrap();
    out
}

#[test]
fn prop_compress_roundtrip_every_mode_and_shape() {
    // every codec, over random shapes *including* the degenerate ones:
    // empty (0×c, r×0), single-entry, and odd/1-wide layouts. `None` is
    // bit-exact; `F32`/`Int8` stay within their documented per-entry
    // quantization error.
    property("compressed matrix roundtrip", 60, |g| {
        let (rows, cols) = match g.usize_in(0, 5) {
            0 => (0, g.usize_in(0, 6)), // empty: no rows
            1 => (g.usize_in(1, 6), 0), // empty: no columns
            2 => (1, 1),                // single entry
            3 => (g.usize_in(1, 9) * 2 - 1, g.usize_in(1, 4) * 2 - 1), // odd×odd
            4 => (g.usize_in(1, 20), 1), // single column
            _ => (g.usize_in(1, 20), g.usize_in(1, 10)),
        };
        let m = g.mat(rows, cols);

        let exact = compress_roundtrip(&m, Compression::None);
        assert_eq!(exact, m, "None must be bit-exact for {rows}x{cols}");

        let f32back = compress_roundtrip(&m, Compression::F32);
        assert_eq!(f32back.shape(), (rows, cols));
        for (y, x) in f32back.as_slice().iter().zip(m.as_slice()) {
            // |x| ≤ ~6σ here, far inside f32 range: relative 2⁻²⁴ bound
            assert!((y - x).abs() <= x.abs() * 1e-7 + 1e-300, "f32 entry {y} vs {x}");
        }

        let q8 = compress_roundtrip(&m, Compression::Int8);
        assert_eq!(q8.shape(), (rows, cols));
        for j in 0..cols {
            let col_max = (0..rows).map(|i| m[(i, j)].abs()).fold(0.0f64, f64::max);
            let step = col_max / 127.0;
            for i in 0..rows {
                assert!(
                    (q8[(i, j)] - m[(i, j)]).abs() <= step / 2.0 + 1e-12,
                    "int8 entry ({i},{j}) off by more than half a step"
                );
            }
        }
    });
}

#[test]
fn prop_privacy_noise_seeded_per_client_and_round() {
    property("privacy noise determinism", 40, |g| {
        let rows = g.usize_in(1, 12);
        let cols = g.usize_in(1, 4);
        let base = g.mat(rows, cols);
        let sigma = g.f64_in(1e-6, 2.0);
        let client = g.usize_in(0, 64);
        let round = g.usize_in(0, 100) as u32;

        // same (client, round) ⇒ bitwise-identical noise
        let mut a = base.clone();
        let mut b = base.clone();
        perturb_update(&mut a, sigma, client, round);
        perturb_update(&mut b, sigma, client, round);
        assert_eq!(a, b, "noise must be deterministic per (client, round)");
        assert_ne!(a, base, "σ > 0 must actually perturb");

        // a different client or round draws a different stream
        let mut c = base.clone();
        perturb_update(&mut c, sigma, client + 1, round);
        assert_ne!(a, c, "clients must not share a noise stream");
        let mut d = base.clone();
        perturb_update(&mut d, sigma, client, round + 1);
        assert_ne!(a, d, "rounds must not share a noise stream");

        // ε → ∞ ⇒ σ = 0 ⇒ exactly zero noise
        let sigma_inf = gaussian_sigma(f64::INFINITY, 1e-5, g.f64_in(0.1, 10.0));
        assert_eq!(sigma_inf, 0.0);
        let mut e = base.clone();
        perturb_update(&mut e, sigma_inf, client, round);
        assert_eq!(e, base, "ε = ∞ must leave the update untouched");

        // σ(ε) is monotone decreasing in ε
        let delta = 1e-5;
        let sens = g.f64_in(0.1, 10.0);
        let eps = g.f64_in(0.01, 10.0);
        assert!(gaussian_sigma(eps, delta, sens) > gaussian_sigma(eps * 2.0, delta, sens));
    });
}

#[test]
fn prop_aggregation_mean_bounds() {
    property("aggregation stays in convex hull", 30, |g| {
        let e = g.usize_in(1, 6);
        let us: Vec<Mat> = (0..e).map(|_| g.mat(4, 3)).collect();
        let weights = vec![1usize; e];
        let kind = if g.bool() { Aggregation::Uniform } else { Aggregation::WeightedByCols };
        let mean = aggregate(kind, &us, &weights);
        for i in 0..4 {
            for j in 0..3 {
                let lo = us.iter().map(|u| u[(i, j)]).fold(f64::INFINITY, f64::min);
                let hi = us.iter().map(|u| u[(i, j)]).fold(f64::NEG_INFINITY, f64::max);
                let v = mean[(i, j)];
                assert!(v >= lo - 1e-12 && v <= hi + 1e-12, "{v} not in [{lo}, {hi}]");
            }
        }
    });
}

#[test]
fn prop_inner_sweep_monotone_descent() {
    property("inner sweep descends", 25, |g| {
        let m_dim = g.usize_in(5, 25);
        let n_dim = g.usize_in(3, 25);
        let r = g.usize_in(1, 3.min(m_dim).min(n_dim));
        let hyper = FactorHyper {
            rank: r,
            rho: g.f64_in(1e-3, 1.0),
            lambda: g.f64_in(0.05, 3.0),
            inner_sweeps: 1,
        };
        let m_block = g.mat(m_dim, n_dim);
        let u = g.mat(m_dim, r);
        let mut state = ClientState::zeros(m_dim, n_dim, r);
        let mut ws = Workspace::new(m_dim, n_dim, r);
        let mut prev = inner_objective(&u, &m_block, &state, &hyper);
        for _ in 0..4 {
            inner_sweep(&u, &m_block, &mut state, &hyper, pool::global(), &mut ws).unwrap();
            let cur = inner_objective(&u, &m_block, &state, &hyper);
            assert!(cur <= prev * (1.0 + 1e-10) + 1e-10, "{cur} > {prev}");
            prev = cur;
        }
    });
}

#[test]
fn prop_fused_tile_sweep_matches_multipass_oracle() {
    // the fused column-tile pipeline (one DRAM pass per sweep) must agree
    // with the preserved multi-pass formulation to 1e-12 over random
    // shapes, hyperparameters, and warm-started states — including the
    // gradient's slot-ordered reduction
    property("fused tile == multipass oracle", 20, |g| {
        let m_dim = g.usize_in(4, 80);
        let n_dim = g.usize_in(2, 90);
        let r = g.usize_in(1, 4.min(m_dim).min(n_dim));
        let hyper = FactorHyper {
            rank: r,
            rho: g.f64_in(1e-3, 1.0),
            lambda: g.f64_in(0.05, 3.0),
            inner_sweeps: 1,
        };
        let m_block = g.mat(m_dim, n_dim);
        let u = g.mat(m_dim, r);
        let n_frac = g.f64_in(0.1, 1.0);

        let mut st_fused = ClientState::zeros(m_dim, n_dim, r);
        let mut ws = Workspace::new(m_dim, n_dim, r);
        let mut st_oracle = st_fused.clone();
        let mut ows = oracle::MultipassWorkspace::new(m_dim, n_dim, r);

        for _ in 0..3 {
            inner_sweep(&u, &m_block, &mut st_fused, &hyper, pool::global(), &mut ws).unwrap();
            oracle::inner_sweep(&u, &m_block, &mut st_oracle, &hyper, &mut ows);
        }
        u_gradient_into(&u, &m_block, &st_fused, &hyper, n_frac, pool::global(), &mut ws)
            .unwrap();
        oracle::u_gradient_into(&u, &m_block, &st_oracle, &hyper, n_frac, &mut ows);

        let rel = |a: &Mat, b: &Mat| (a - b).frob_norm() / b.frob_norm().max(1.0);
        assert!(rel(&st_fused.v, &st_oracle.v) < 1e-12, "V {}", rel(&st_fused.v, &st_oracle.v));
        assert!(rel(&st_fused.s, &st_oracle.s) < 1e-12, "S {}", rel(&st_fused.s, &st_oracle.s));
        assert!(rel(&ws.grad, &ows.grad) < 1e-12, "grad {}", rel(&ws.grad, &ows.grad));
    });
}

#[test]
fn prop_local_epoch_identical_across_thread_counts() {
    // --threads 1/2/4 must be *bitwise* identical on the same seed: the
    // slot decomposition and the slot-ordered gradient reduction never
    // depend on thread count
    property("epoch bitwise-deterministic across threads", 6, |g| {
        // wide enough that several panels exist at every m (panel width
        // shrinks as m grows; m ≥ 128 → w ≤ 128)
        let m_dim = g.usize_in(128, 300);
        let n_dim = g.usize_in(150, 320);
        let r = g.usize_in(1, 5);
        let hyper = FactorHyper::default_for(m_dim, n_dim, r);
        let m_block = g.mat(m_dim, n_dim);
        let u0 = g.mat(m_dim, r);
        let mut results = Vec::new();
        for threads in [1usize, 2, 4] {
            let kernel = NativeKernel::with_threads(threads);
            let mut u = u0.clone();
            let mut state = ClientState::zeros(m_dim, n_dim, r);
            let mut ws = Workspace::new(m_dim, n_dim, r);
            let out = kernel
                .local_epoch(&mut u, &m_block, &mut state, &hyper, 0.5, 1e-3, 2, &mut ws)
                .unwrap();
            results.push((u, state.v, state.s, out.grad_norm.to_bits(), out.lipschitz.to_bits()));
        }
        assert_eq!(results[0], results[1], "threads=1 vs 2 diverged");
        assert_eq!(results[0], results[2], "threads=1 vs 4 diverged");
    });
}

#[test]
fn prop_shrink_never_increases_magnitude() {
    property("shrink contracts", 40, |g| {
        let ar = g.usize_in(1, 10);
        let ac = g.usize_in(1, 10);
        let a = g.mat(ar, ac);
        let lam = g.f64_in(0.0, 2.0);
        let s = shrink(&a, lam);
        for (x, y) in a.as_slice().iter().zip(s.as_slice()) {
            assert!(y.abs() <= x.abs() + 1e-15);
            assert!(x.signum() == y.signum() || *y == 0.0);
        }
    });
}

#[test]
fn prop_svd_reconstruction_and_spectrum() {
    property("svd reconstructs", 15, |g| {
        let rows = g.usize_in(2, 15);
        let cols = g.usize_in(2, 15);
        let a = g.mat(rows, cols);
        let svd = svd_jacobi(&a);
        let k = rows.min(cols);
        let back = dcf_pca::linalg::reconstruct(&svd, k);
        let rel = (&back - &a).frob_norm() / a.frob_norm().max(1e-12);
        assert!(rel < 1e-9, "rel {rel}");
        // spectral norm dominates every matvec ratio
        let x = g.mat(cols, 1);
        let ax = matmul(&a, &x);
        assert!(ax.frob_norm() <= svd.s[0] * x.frob_norm() * (1.0 + 1e-9));
    });
}

#[test]
fn prop_gemm_transpose_identities() {
    property("gemm transpose identities", 30, |g| {
        let m = g.usize_in(1, 12);
        let k = g.usize_in(1, 12);
        let n = g.usize_in(1, 12);
        let a = g.mat(m, k);
        let b = g.mat(k, n);
        // (A·B)ᵀ = Bᵀ·Aᵀ
        let ab_t = matmul(&a, &b).transpose();
        let bt_at = matmul(&b.transpose(), &a.transpose());
        assert!((&ab_t - &bt_at).frob_norm() < 1e-10);
        // Aᵀ·B via matmul_tn equals explicit transpose
        let c = g.mat(m, n);
        let tn = matmul_tn(&a, &c);
        let explicit = matmul(&a.transpose(), &c);
        assert!((&tn - &explicit).frob_norm() < 1e-10);
        // A·Bᵀ via matmul_nt
        let d = g.mat(n, k);
        let nt = matmul_nt(&a, &d);
        let explicit2 = matmul(&a, &d.transpose());
        assert!((&nt - &explicit2).frob_norm() < 1e-10);
    });
}

#[test]
fn prop_problem_generator_invariants() {
    property("problem generator invariants", 20, |g| {
        let n = g.usize_in(10, 40);
        let rank = g.usize_in(1, 3);
        let s = g.f64_in(0.01, 0.3);
        let spec = dcf_pca::rpca::problem::ProblemSpec::square(n, rank, s);
        let p = spec.generate(g.usize_in(0, 10_000) as u64);
        // M = L0 + S0 exactly
        assert_eq!(&p.l0 + &p.s0, p.observed);
        // support size
        assert_eq!(p.corruption_count(), ((s * (n * n) as f64).floor()) as usize);
        // rank of L0
        let sv = singular_values(&p.l0);
        if rank < n {
            assert!(sv[rank] < 1e-8 * sv[0].max(1e-300));
        }
    });
}

#[test]
fn prop_json_roundtrip_fuzzed() {
    use dcf_pca::util::json::Json;
    property("json roundtrip", 40, |g| {
        // build a random JSON value
        fn build(g: &mut dcf_pca::testing::Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize_in(0, 3) } else { g.usize_in(0, 5) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool()),
                2 => Json::Num((g.f64_in(-1e6, 1e6) * 100.0).round() / 100.0),
                3 => Json::Str(format!("s{}-\"q\"\n", g.usize_in(0, 99))),
                4 => Json::Arr((0..g.usize_in(0, 4)).map(|_| build(g, depth - 1)).collect()),
                _ => {
                    let mut m = std::collections::BTreeMap::new();
                    for i in 0..g.usize_in(0, 4) {
                        m.insert(format!("k{i}"), build(g, depth - 1));
                    }
                    Json::Obj(m)
                }
            }
        }
        let v = build(g, 3);
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "text was: {text}");
    });
}
