//! SIMD-vs-scalar parity through the *public* API on adversarial inputs:
//! denormals, ±0.0, NaN/Inf, lengths straddling the vector width, and
//! strided panel views. The in-crate unit tests (`linalg::simd`,
//! `linalg::gemm`) pin each primitive; this suite pins the wired-up entry
//! points the solvers actually call, so a dispatch regression anywhere in
//! the plumbing fails here. Under `DCF_PCA_FORCE_SCALAR=1` (the CI
//! forced-scalar job) every comparison degenerates to scalar-vs-scalar
//! and must still hold — the contract is arm-independent.

use dcf_pca::algorithms::factor::{oracle, ClientState, FactorHyper};
use dcf_pca::coordinator::compress::{put_mat_compressed, read_mat_compressed, Compression};
use dcf_pca::coordinator::kernel::{LocalUpdateKernel, NativeKernel};
use dcf_pca::coordinator::transport::framing::Reader;
use dcf_pca::linalg::{
    cholesky_shifted_into, gemm, gram_into, matmul_into, matmul_nt_into, matmul_tn_into,
    matvec_into, residual_shrink_into, shrink_dual_into, shrink_into, shrink_sub_into, simd,
    sub_into, GradCtx, Mat, PanelCtx, PanelScratch, PanelView, Workspace,
};
use dcf_pca::rng::Pcg64;

/// Everything the elementwise kernels must agree on bitwise, including
/// the values where branchy scalar code and branchless SIMD most easily
/// diverge: signed zeros, subnormals at both extremes, NaN, ±Inf.
const SPECIALS: [f64; 16] = [
    0.0,
    -0.0,
    1.0,
    -1.5,
    1e-300,
    -1e-300,
    5e-324,
    -5e-324,
    1e6,
    -1e6,
    f64::NAN,
    f64::INFINITY,
    f64::NEG_INFINITY,
    0.1,
    -0.7,
    3.25,
];

/// Finite subset for the accumulation kernels, where mixing ±Inf would
/// make the result order-dependent (Inf − Inf) rather than expose bugs.
const FINITE: [f64; 12] = [
    0.0, -0.0, 1.0, -1.5, 1e-300, -1e-300, 5e-324, -5e-324, 1e6, -1e6, 0.1, -0.7,
];

/// Lengths straddling the 4-wide vector width and its unrolled multiples.
const LENS: [usize; 13] = [1, 2, 3, 4, 5, 7, 8, 15, 16, 17, 31, 32, 33];

/// Deterministic pool sampling, decorrelated between operands by `salt`.
fn adversarial(pool: &[f64], len: usize, salt: usize) -> Vec<f64> {
    (0..len).map(|i| pool[(i * 7 + salt * 3 + 1) % pool.len()]).collect()
}

fn assert_bits_eq(got: &[f64], want: &[f64], what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let ok = g.to_bits() == w.to_bits() || (g.is_nan() && w.is_nan());
        assert!(ok, "{what}[{i}]: {g:e} ({:#018x}) vs {w:e} ({:#018x})", g.to_bits(), w.to_bits());
    }
}

fn assert_close(got: &[f64], want: &[f64], tol: f64, what: &str) {
    assert_eq!(got.len(), want.len(), "{what}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        if g.is_nan() && w.is_nan() {
            continue;
        }
        let denom = g.abs().max(w.abs()).max(1.0);
        assert!((g - w).abs() / denom < tol, "{what}[{i}]: {g:e} vs {w:e}");
    }
}

#[test]
fn elementwise_entry_points_bitwise_match_scalar_on_specials() {
    for &len in &LENS {
        for salt in 0..4 {
            let a = adversarial(&SPECIALS, len, salt);
            let b = adversarial(&SPECIALS, len, salt + 1);
            let y = adversarial(&SPECIALS, len, salt + 2);
            let mut got = vec![0.0; len];
            let mut want = vec![0.0; len];

            shrink_into(&mut got, &a, 0.3);
            simd::scalar::shrink(&mut want, &a, 0.3);
            assert_bits_eq(&got, &want, "shrink_into");

            shrink_sub_into(&mut got, &a, &b, 0.3);
            simd::scalar::shrink_sub(&mut want, &a, &b, 0.3);
            assert_bits_eq(&got, &want, "shrink_sub_into");

            shrink_dual_into(&mut got, &a, &b, &y, 0.25, 0.3);
            simd::scalar::shrink_dual(&mut want, &a, &b, &y, 0.25, 0.3);
            assert_bits_eq(&got, &want, "shrink_dual_into");
        }
    }
}

#[test]
fn single_special_value_is_position_exact() {
    // one NaN/Inf/subnormal dropped at the head, middle, or tail of an
    // otherwise-finite buffer must affect exactly its own lane in both
    // the vector body and the scalar tail
    for &len in &LENS {
        for pos in [0, len / 2, len - 1] {
            for special in [f64::NAN, f64::INFINITY, f64::NEG_INFINITY, -5e-324] {
                let mut a = adversarial(&FINITE, len, 1);
                a[pos] = special;
                let mut got = vec![0.0; len];
                let mut want = vec![0.0; len];
                shrink_into(&mut got, &a, 0.4);
                simd::scalar::shrink(&mut want, &a, 0.4);
                assert_bits_eq(&got, &want, "shrink_into (planted special)");
            }
        }
    }
}

#[test]
fn mat_level_entry_points_bitwise_match_composed_scalar() {
    for &(r, c) in &[(1usize, 1usize), (3, 5), (7, 9), (5, 33)] {
        let len = r * c;
        let m = Mat::from_vec(r, c, adversarial(&SPECIALS, len, 0));
        let uv = Mat::from_vec(r, c, adversarial(&SPECIALS, len, 1));

        let mut diff = vec![0.0; len];
        simd::scalar::sub(&mut diff, m.as_slice(), uv.as_slice());
        let mut out = Mat::zeros(r, c);
        sub_into(&mut out, &m, &uv);
        assert_bits_eq(out.as_slice(), &diff, "sub_into");

        let mut s = Mat::zeros(r, c);
        residual_shrink_into(&mut s, &m, &uv, 0.2);
        let mut want = vec![0.0; len];
        simd::scalar::shrink(&mut want, &diff, 0.2);
        assert_bits_eq(s.as_slice(), &want, "residual_shrink_into");
    }
}

#[test]
fn matmul_family_matches_scalar_twins_on_denormal_inputs() {
    // ragged shapes around the blocking and unroll boundaries; inputs
    // drawn from the finite pool so subnormal×subnormal underflow and
    // signed-zero products are exercised without order-dependent Inf
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (2, 3, 4), (5, 7, 9), (9, 33, 17), (33, 8, 31)] {
        let a = Mat::from_vec(m, k, adversarial(&FINITE, m * k, 0));
        let b = Mat::from_vec(k, n, adversarial(&FINITE, k * n, 1));
        let mut got = Mat::zeros(m, n);
        let mut want = Mat::zeros(m, n);
        matmul_into(&mut got, &a, &b);
        gemm::matmul_acc_scalar(&mut want, &a, &b, 1.0, 0.0);
        assert_close(got.as_slice(), want.as_slice(), 1e-12, "matmul_into");

        let x = Mat::from_vec(m, k, adversarial(&FINITE, m * k, 2));
        let y = Mat::from_vec(m, n, adversarial(&FINITE, m * n, 3));
        let mut got = Mat::zeros(k, n);
        let mut want = Mat::zeros(k, n);
        matmul_tn_into(&mut got, &x, &y);
        gemm::matmul_tn_into_scalar(&mut want, &x, &y);
        assert_close(got.as_slice(), want.as_slice(), 1e-12, "matmul_tn_into");

        let u = Mat::from_vec(m, k, adversarial(&FINITE, m * k, 4));
        let v = Mat::from_vec(n, k, adversarial(&FINITE, n * k, 5));
        let mut got = Mat::zeros(m, n);
        let mut want = Mat::zeros(m, n);
        matmul_nt_into(&mut got, &u, &v);
        gemm::matmul_nt_into_scalar(&mut want, &u, &v);
        assert_close(got.as_slice(), want.as_slice(), 1e-12, "matmul_nt_into");

        let mut gg = Mat::zeros(k, k);
        let mut gw = Mat::zeros(k, k);
        gram_into(&mut gg, &x);
        gemm::gram_into_scalar(&mut gw, &x);
        assert_close(gg.as_slice(), gw.as_slice(), 1e-12, "gram_into");

        let xv = adversarial(&FINITE, k, 6);
        let mut yg = vec![0.0; m];
        let mut yw = vec![0.0; m];
        matvec_into(&mut yg, &a, &xv);
        gemm::matvec_into_scalar(&mut yw, &a, &xv);
        assert_close(&yg, &yw, 1e-12, "matvec_into");
    }
}

/// Runs the fused sweep + polish over every panel of a 9×13 block at
/// panel width 5 — a ragged 4-row remainder (9 = 4+4+1) and a ragged
/// last panel (13 = 5+5+3) — once with the resident strided view
/// (`row_stride = n_i, col_offset = j0`) and once with each panel packed
/// contiguous (`row_stride = w_k, col_offset = 0`, the streamed-shard
/// layout). The two producers must be bitwise indistinguishable.
#[test]
fn panel_pipeline_is_bitwise_identical_for_strided_and_packed_views() {
    let (m, n_i, p, w) = (9usize, 13usize, 3usize, 5usize);
    let mut rng = Pcg64::new(0xC0FFEE);
    let u = Mat::gaussian(m, p, &mut rng);
    let mobs = Mat::from_vec(m, n_i, adversarial(&FINITE, m * n_i, 2));
    let mut gram = Mat::zeros(p, p);
    gram_into(&mut gram, &u);
    let mut chol = Mat::zeros(p, p);
    assert!(cholesky_shifted_into(&mut chol, &gram, 0.5), "ridge Gram must be SPD");

    let run = |packed: bool| -> (Mat, Mat) {
        let mut v = Mat::zeros(n_i, p);
        let mut s = Mat::zeros(m, n_i);
        {
            let ctx = PanelCtx::new(&u, &chol, m, n_i, w, &mut v, &mut s, 0.07);
            let mut scratch = PanelScratch::new(m, p, w);
            let md = mobs.as_slice();
            for k in 0..ctx.panels() {
                let j0 = k * w;
                let wk = (j0 + w).min(n_i) - j0;
                if packed {
                    let mut buf = vec![0.0; m * wk];
                    for i in 0..m {
                        let src = &md[i * n_i + j0..i * n_i + j0 + wk];
                        buf[i * wk..(i + 1) * wk].copy_from_slice(src);
                    }
                    ctx.sweep_panel(k, PanelView::new(&buf, wk, 0), &mut scratch);
                    ctx.polish_panel(k, PanelView::new(&buf, wk, 0), &mut scratch);
                } else {
                    ctx.sweep_panel(k, PanelView::new(md, n_i, j0), &mut scratch);
                    ctx.polish_panel(k, PanelView::new(md, n_i, j0), &mut scratch);
                }
            }
        }
        (v, s)
    };

    let (v_strided, s_strided) = run(false);
    let (v_packed, s_packed) = run(true);
    assert_bits_eq(v_strided.as_slice(), v_packed.as_slice(), "V strided vs packed");
    assert_bits_eq(s_strided.as_slice(), s_packed.as_slice(), "S strided vs packed");

    // same check for the gradient accumulator
    let grad = |packed: bool| -> Mat {
        let ctx = GradCtx::new(&u, m, n_i, w, &v_strided, &s_strided);
        let mut scratch = PanelScratch::new(m, p, w);
        scratch.grad_acc.fill(0.0);
        let md = mobs.as_slice();
        for k in 0..ctx.panels() {
            let j0 = k * w;
            let wk = (j0 + w).min(n_i) - j0;
            if packed {
                let mut buf = vec![0.0; m * wk];
                for i in 0..m {
                    let src = &md[i * n_i + j0..i * n_i + j0 + wk];
                    buf[i * wk..(i + 1) * wk].copy_from_slice(src);
                }
                ctx.grad_panel(k, PanelView::new(&buf, wk, 0), &mut scratch);
            } else {
                ctx.grad_panel(k, PanelView::new(md, n_i, j0), &mut scratch);
            }
        }
        scratch.grad_acc
    };
    assert_bits_eq(grad(false).as_slice(), grad(true).as_slice(), "grad strided vs packed");
}

#[test]
fn fused_epoch_agrees_with_multipass_oracle_at_edge_shapes() {
    // edge shapes: ragged 4-row remainders, n_i < m, n_i > m
    for &(m, n_i, p) in &[(9usize, 13usize, 3usize), (33, 17, 4), (21, 70, 5)] {
        let mut rng = Pcg64::new((m * 1000 + n_i) as u64);
        let u0 = Mat::gaussian(m, p, &mut rng);
        let mobs = Mat::gaussian(m, n_i, &mut rng);
        let hyper = FactorHyper::default_for(m, n_i, p);

        let mut u_fused = u0.clone();
        let mut st_fused = ClientState::zeros(m, n_i, p);
        let mut ws = Workspace::new(m, n_i, p);
        let kernel = NativeKernel::with_threads(1);
        kernel
            .local_epoch(&mut u_fused, &mobs, &mut st_fused, &hyper, 1.0, 1e-3, 2, &mut ws)
            .unwrap();

        let mut u_oracle = u0.clone();
        let mut st_oracle = ClientState::zeros(m, n_i, p);
        let mut ows = oracle::MultipassWorkspace::new(m, n_i, p);
        oracle::local_epoch(&mut u_oracle, &mobs, &mut st_oracle, &hyper, 1.0, 1e-3, 2, &mut ows);

        assert_close(u_fused.as_slice(), u_oracle.as_slice(), 1e-10, "U fused vs multipass");
        assert_close(st_fused.v.as_slice(), st_oracle.v.as_slice(), 1e-10, "V fused vs multipass");
        assert_close(st_fused.s.as_slice(), st_oracle.s.as_slice(), 1e-10, "S fused vs multipass");
    }
}

#[test]
fn epoch_is_bitwise_identical_across_thread_counts() {
    // the dispatch invariant: within one dispatch arm, the slot
    // decomposition fixes the arithmetic, so thread count must not
    // change a single bit — including on blocks seeded with subnormals.
    // m = 602 forces panel width 27 (three ragged panels over n_i = 70)
    // plus a ragged 4-row remainder, so the panels genuinely land on
    // different threads at t > 1
    let (m, n_i, p) = (602usize, 70usize, 5usize);
    let hyper = FactorHyper::default_for(m, n_i, p);
    let mut rng = Pcg64::new(99);
    let u0 = Mat::gaussian(m, p, &mut rng);
    let mut mdata = Mat::gaussian(m, n_i, &mut rng);
    for (i, x) in mdata.as_mut_slice().iter_mut().enumerate() {
        if i % 17 == 0 {
            *x = FINITE[(i / 17) % FINITE.len()];
        }
    }

    let run = |threads: usize| -> (Mat, Mat, Mat) {
        let mut u = u0.clone();
        let mut st = ClientState::zeros(m, n_i, p);
        let mut ws = Workspace::new(m, n_i, p);
        let kernel = NativeKernel::with_threads(threads);
        kernel.local_epoch(&mut u, &mdata, &mut st, &hyper, 1.0, 1e-3, 2, &mut ws).unwrap();
        (u, st.v, st.s)
    };
    let (u1, v1, s1) = run(1);
    for threads in [2usize, 4] {
        let (ut, vt, st) = run(threads);
        assert_bits_eq(u1.as_slice(), ut.as_slice(), "U across thread counts");
        assert_bits_eq(v1.as_slice(), vt.as_slice(), "V across thread counts");
        assert_bits_eq(s1.as_slice(), st.as_slice(), "S across thread counts");
    }
}

#[test]
fn f32_codec_matches_scalar_casts_bitwise() {
    // the wire narrowing must be exactly `x as f32` / widening exactly
    // `x as f64` under either dispatch arm, including on subnormals that
    // flush to f32 zero and values straddling the chunked-conversion
    // boundary (len > 512 exercises a full chunk plus a ragged one)
    for &(r, c) in &[(1usize, 1usize), (5, 7), (9, 33), (3, 257)] {
        let m = Mat::from_vec(r, c, adversarial(&FINITE, r * c, 3));
        let mut buf = Vec::new();
        put_mat_compressed(&mut buf, &m, Compression::F32);
        let mut rd = Reader::new(&buf);
        let out = read_mat_compressed(&mut rd).unwrap();
        rd.expect_end().unwrap();
        let want: Vec<f64> = m.as_slice().iter().map(|&x| (x as f32) as f64).collect();
        assert_bits_eq(out.as_slice(), &want, "f32 codec roundtrip");
    }
}
