//! Quickstart: recover a corrupted low-rank matrix with DCF-PCA.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//!
//! Generates the paper's synthetic instance at n = 200 (§4.1), runs the
//! distributed solver with 10 clients over the in-process transport, and
//! prints the recovery error (Eq. 30), the per-round convergence, and
//! the measured communication cost (Eq. 28).

use dcf_pca::coordinator::driver::{run_dcf_pca, DcfPcaConfig};
use dcf_pca::rpca::problem::ProblemSpec;

fn main() -> dcf_pca::anyhow::Result<()> {
    // m = n = 200, true rank 10 (= 0.05n), 5% of entries corrupted by
    // ±√(mn) spikes — the paper's standard generator.
    let spec = ProblemSpec::paper_default(200);
    let problem = spec.generate(42);
    println!(
        "problem: {}x{} observed = rank-{} L0 + {}-sparse S0 (spike magnitude {:.0})",
        spec.m,
        spec.n,
        spec.rank,
        problem.corruption_count(),
        problem.spike_scale()
    );

    // 10 clients, 2 local iterations per round (Algorithm 1 defaults).
    let cfg = DcfPcaConfig::default_for(&spec)
        .with_clients(10)
        .with_rounds(40)
        .with_k_local(2);
    let result = run_dcf_pca(&problem, &cfg)?;

    println!("\nround   err (Eq.30)   ‖∇U‖       η        dispersion");
    for r in result.rounds.iter().step_by(5) {
        println!(
            "{:>5}   {:>9.3e}   {:>8.2e}  {:>7.1e}  {:>9.2e}",
            r.round,
            r.err.unwrap_or(f64::NAN),
            r.mean_grad_norm,
            r.eta,
            r.dispersion
        );
    }

    println!(
        "\nfinal recovery error (after debias polish): {:.3e}",
        result.final_error.unwrap()
    );
    println!(
        "communication: {} rounds x {} B/round = {} KiB total (Eq. 28 payload: 2*E*m*r*8 = {} B/round)",
        result.comm.rounds,
        result.comm.per_round() as u64,
        result.comm.total() / 1024,
        2 * cfg.clients * spec.m * spec.rank * 8,
    );
    println!("wall time: {:?}", result.wall);
    Ok(())
}
