//! END-TO-END driver: the full three-layer stack on a real workload.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_pjrt
//! ```
//!
//! Proves every layer composes:
//!
//!   L1  Pallas kernels (gram_rhs / residual_shrink / u_grad) …
//!   L2  … inside the JAX `client_update`, AOT-lowered to HLO text …
//!   RT  … loaded + compiled via PJRT from rust (zero python here) …
//!   L3  … driven by the rust federated coordinator (Algorithm 1).
//!
//! Workload: the paper's synthetic RPCA instance at m = n = 60, E = 5
//! clients (12 columns each → artifact variant client_m60_n12_r3_k2_j3),
//! 30 rounds, K = 2. The run logs the Eq. 30 error per round for the
//! PJRT path AND the native-rust path side by side (they must agree to
//! f32 precision), then reports the headline metrics recorded in
//! EXPERIMENTS.md §E2E.

use std::sync::Arc;

use dcf_pca::algorithms::Schedule;
use dcf_pca::coordinator::driver::{run_dcf_pca, DcfPcaConfig, KernelSpec};
use dcf_pca::rpca::problem::ProblemSpec;
use dcf_pca::runtime::PjrtKernel;

fn main() -> dcf_pca::anyhow::Result<()> {
    let spec = ProblemSpec::square(60, 3, 0.05);
    let problem = spec.generate(42);

    // fixed η so both backends follow the identical trajectory
    // (the adaptive schedule feeds back f32-rounded curvature estimates,
    // which would make the comparison fuzzier than necessary)
    let base = DcfPcaConfig::default_for(&spec)
        .with_clients(5)
        .with_rounds(60)
        .with_k_local(2)
        .with_schedule(Schedule::Const { eta: 2e-2 })
        .with_seed(42);

    println!("loading AOT artifacts (PJRT CPU)…");
    let kernel = match PjrtKernel::load("artifacts") {
        Ok(k) => k,
        Err(err) => {
            println!("SKIP: PJRT backend unavailable ({err:#})");
            println!("build the artifacts (`make artifacts`) and restore the xla runtime to run this end-to-end demo.");
            return Ok(());
        }
    };
    let mut pjrt_cfg = base.clone();
    pjrt_cfg.kernel = KernelSpec::Custom(Arc::new(kernel));

    let t0 = std::time::Instant::now();
    let pjrt = run_dcf_pca(&problem, &pjrt_cfg)?;
    let pjrt_wall = t0.elapsed();

    let t0 = std::time::Instant::now();
    let native = run_dcf_pca(&problem, &base)?;
    let native_wall = t0.elapsed();

    println!("\nround    err (pjrt)     err (native)   |Δ|");
    for (p, n) in pjrt.rounds.iter().zip(&native.rounds).step_by(3) {
        let (ep, en) = (p.err.unwrap(), n.err.unwrap());
        println!(
            "{:>5}    {:>10.4e}    {:>10.4e}   {:>8.1e}",
            p.round,
            ep,
            en,
            (ep - en).abs()
        );
    }

    let (ep, en) = (pjrt.final_error.unwrap(), native.final_error.unwrap());
    println!("\nheadline (recorded in EXPERIMENTS.md §E2E):");
    println!("  final err  pjrt:   {ep:.4e}  ({pjrt_wall:?})");
    println!("  final err  native: {en:.4e}  ({native_wall:?})");
    println!(
        "  comm: {} B/round over {} rounds (Eq. 28 payload {} B)",
        pjrt.comm.per_round() as u64,
        pjrt.comm.rounds,
        2 * 5 * spec.m * spec.rank * 8
    );

    // layers must agree: same trajectory up to f32 rounding
    let max_gap = pjrt
        .rounds
        .iter()
        .zip(&native.rounds)
        .map(|(p, n)| (p.err.unwrap() - n.err.unwrap()).abs() / n.err.unwrap().max(1e-12))
        .fold(0.0f64, f64::max);
    println!("  max per-round relative err gap pjrt vs native: {max_gap:.2e}");
    dcf_pca::ensure!(max_gap < 1e-2, "backends diverged: {max_gap}");
    dcf_pca::ensure!(ep < 1e-3, "PJRT path failed to recover: err {ep}");
    println!("\nE2E OK: all three layers compose.");
    Ok(())
}
