//! Privacy-preserving federated RPCA over real TCP sockets (§2.2).
//!
//! ```sh
//! cargo run --release --example federated_privacy
//! ```
//!
//! Five parties hold column blocks of a shared data matrix; parties 1
//! and 3 declare their blocks private. The server and every client run
//! on separate threads connected by localhost TCP (the same code path as
//! `dcf-pca serve` / `dcf-pca worker` across machines). The run
//! demonstrates the paper's privacy claim mechanically:
//!
//! - every byte on each wire is metered: client i uploads exactly
//!   `rounds × (m·r floats + header)` — far less than its data block,
//!   and *independent of n_i* (nothing data-sized ever leaves);
//! - the recovered (L_i, S_i) come back only for public parties.

use dcf_pca::algorithms::factor::FactorHyper;
use dcf_pca::coordinator::client::{run_client, ClientConfig, FaultPlan};
use dcf_pca::coordinator::kernel::NativeKernel;
use dcf_pca::coordinator::protocol::update_wire_size;
use dcf_pca::coordinator::server::{run_server, ServerConfig};
use dcf_pca::coordinator::transport::tcp::{TcpAcceptor, TcpChannel};
use dcf_pca::coordinator::transport::Channel;
use dcf_pca::coordinator::PrivacySpec;
use dcf_pca::rpca::partition::ColumnPartition;
use dcf_pca::rpca::problem::ProblemSpec;

const E: usize = 5;
const ROUNDS: usize = 25;

fn main() -> dcf_pca::anyhow::Result<()> {
    let spec = ProblemSpec::paper_default(150);
    let problem = spec.generate(7);
    let partition = ColumnPartition::even(spec.n, E);
    let private = PrivacySpec::with_private([1usize, 3]);

    let acceptor = TcpAcceptor::bind("127.0.0.1:0")?;
    let addr = acceptor.local_addr()?;
    println!("server on {addr}; parties 1 and 3 are private");

    // spawn the five parties as real TCP clients
    let mut party_handles = Vec::new();
    for id in 0..E {
        let addr = addr.clone();
        let (a, b) = partition.range(id);
        let m_block = problem.observed.cols_range(a, b);
        let truth = (problem.l0.cols_range(a, b), problem.s0.cols_range(a, b));
        let hyper = FactorHyper::default_for(spec.m, spec.n, spec.rank);
        let n_frac = (b - a) as f64 / spec.n as f64;
        party_handles.push(std::thread::spawn(move || -> dcf_pca::anyhow::Result<u64> {
            let mut ch = TcpChannel::connect(&addr)?;
            let cfg = ClientConfig {
                id,
                job: 0,
                data: Box::new(m_block),
                hyper,
                n_frac,
                polish_sweeps: 3,
                truth: Some(truth),
                faults: FaultPlan::default(),
                compression: dcf_pca::coordinator::Compression::None,
                dp_sigma: 0.0,
            };
            run_client(&mut ch, cfg, &NativeKernel::new())?;
            Ok(ch.bytes_sent())
        }));
    }

    // server side: any accept order works — the engine binds identities
    // from each party's Hello, not from connection order
    let mut channels: Vec<Box<dyn Channel>> = acceptor
        .accept_n(E)?
        .into_iter()
        .map(|c| Box::new(c) as Box<dyn Channel>)
        .collect();
    let mut server_cfg = ServerConfig::new(spec.m, spec.rank, ROUNDS, 2);
    server_cfg.privacy = private.clone();
    server_cfg.err_denominator = Some(problem.l0.frob_norm_sq() + problem.s0.frob_norm_sq());
    let outcome = run_server(&mut channels, &server_cfg)?;

    let revealed: Vec<usize> = outcome.revealed.iter().map(|(i, _, _)| *i).collect();
    println!("\nrevealed blocks: {revealed:?} (withheld: {:?})", outcome.withheld);
    assert_eq!(revealed, vec![0, 2, 4]);
    assert_eq!(outcome.withheld, vec![1, 3]);

    // per-party upload audit
    println!("\nparty   upload (B)   its data block (B)   ratio");
    for (id, h) in party_handles.into_iter().enumerate() {
        let uploaded = h.join().expect("party thread")?;
        let block_bytes = (spec.m * partition.size(id) * 8) as u64;
        println!(
            "{id:>5}   {uploaded:>10}   {block_bytes:>18}   {:.1}%",
            100.0 * uploaded as f64 / block_bytes as f64
        );
        // upload = hello + per-round update + final reveal/withhold —
        // the updates dominate and are m×r, independent of the block size
        let update_bytes = (ROUNDS * update_wire_size(spec.m, spec.rank)) as u64;
        assert!(uploaded >= update_bytes, "missing updates?");
        if private.is_private(id) {
            // private parties never upload anything block-sized
            assert!(
                uploaded < update_bytes + 64,
                "party {id} uploaded more than consensus updates + headers"
            );
        }
    }

    if let Some(err) = outcome.rounds.last().and_then(|r| r.err) {
        println!("\ntracked err at last round (all blocks, telemetry): {err:.3e}");
    }
    println!("done: private data never left parties 1 and 3.");
    Ok(())
}
