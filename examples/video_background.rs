//! Background subtraction — the classic RPCA application the paper's
//! introduction motivates: a surveillance-style video is (pixels ×
//! frames); the static background is low-rank across frames, moving
//! foreground objects are sparse. RPCA separates them with no motion
//! model at all.
//!
//! ```sh
//! cargo run --release --example video_background
//! ```
//!
//! We synthesize a 32x32, 60-frame scene: a smooth background with slow
//! global illumination drift (rank ≈ 2) plus a bright 5x5 object moving
//! along a diagonal. Frames are distributed over 6 clients (10 frames
//! each — e.g. cameras buffering locally); DCF-PCA recovers the
//! background model without any client ever sharing raw frames.

use dcf_pca::coordinator::driver::{run_dcf_pca_raw, DcfPcaConfig};
use dcf_pca::linalg::Mat;
use dcf_pca::rpca::problem::ProblemSpec;

const W: usize = 32;
const H: usize = 32;
const FRAMES: usize = 60;

/// Background intensity at pixel (x, y): smooth spatial gradient.
fn background(x: usize, y: usize) -> f64 {
    let (xf, yf) = (x as f64 / W as f64, y as f64 / H as f64);
    40.0 + 25.0 * (1.2 * xf + 0.8 * yf) + 10.0 * (3.0 * xf).sin() * (2.0 * yf).cos()
}

/// Global illumination factor for frame t (slow sinusoidal drift —
/// second background dimension).
fn illumination(t: usize) -> f64 {
    1.0 + 0.12 * (t as f64 * std::f64::consts::TAU / FRAMES as f64).sin()
}

/// Foreground object position at frame t (diagonal sweep).
fn object_pos(t: usize) -> (usize, usize) {
    let f = t as f64 / FRAMES as f64;
    (((W - 6) as f64 * f) as usize, ((H - 6) as f64 * f) as usize)
}

fn main() -> dcf_pca::anyhow::Result<()> {
    // build the video: columns are vectorized frames
    let mut video = Mat::zeros(W * H, FRAMES);
    let mut truth_fg = Mat::zeros(W * H, FRAMES);
    for t in 0..FRAMES {
        let illum = illumination(t);
        let (ox, oy) = object_pos(t);
        for y in 0..H {
            for x in 0..W {
                let px = y * W + x;
                let mut val = background(x, y) * illum;
                if x >= ox && x < ox + 5 && y >= oy && y < oy + 5 {
                    val += 120.0; // bright moving object
                    truth_fg[(px, t)] = 1.0;
                }
                video[(px, t)] = val;
            }
        }
    }

    // 6 clients x 10 frames; rank budget 3 covers background + drift
    let spec = ProblemSpec { m: W * H, n: FRAMES, rank: 3, sparsity: 0.03 };
    let mut cfg = DcfPcaConfig::default_for(&spec)
        .with_clients(6)
        .with_rounds(30)
        .with_k_local(2);
    // foreground pixels are ~120 over background ~40-80; threshold between
    cfg.hyper.lambda = 25.0;
    let result = run_dcf_pca_raw(&video, &cfg)?;

    // evaluate foreground detection from the sparse component
    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut fnn = 0usize;
    for (s_val, fg) in result.s.as_slice().iter().zip(truth_fg.as_slice()) {
        let detected = s_val.abs() > 30.0;
        match (detected, *fg > 0.5) {
            (true, true) => tp += 1,
            (true, false) => fp += 1,
            (false, true) => fnn += 1,
            _ => {}
        }
    }
    let precision = tp as f64 / (tp + fp).max(1) as f64;
    let recall = tp as f64 / (tp + fnn).max(1) as f64;
    let f1 = 2.0 * precision * recall / (precision + recall).max(1e-12);

    // background reconstruction quality on non-object pixels
    let mut bg_err = 0.0;
    let mut bg_norm = 0.0;
    for t in 0..FRAMES {
        let illum = illumination(t);
        for y in 0..H {
            for x in 0..W {
                let px = y * W + x;
                if truth_fg[(px, t)] == 0.0 {
                    let truth = background(x, y) * illum;
                    let diff = result.l[(px, t)] - truth;
                    bg_err += diff * diff;
                    bg_norm += truth * truth;
                }
            }
        }
    }

    println!("video background subtraction over {FRAMES} frames ({W}x{H}):");
    println!("  foreground detection: precision {precision:.3}, recall {recall:.3}, F1 {f1:.3}");
    println!(
        "  background relative error (non-object pixels): {:.3e}",
        (bg_err / bg_norm).sqrt()
    );
    println!(
        "  communication: {} B/round for {} clients (raw frames would be {} B/client-round)",
        result.comm.per_round() as u64,
        cfg.clients,
        W * H * 10 * 8,
    );
    println!("  wall: {:?}", result.wall);

    // ASCII visualization of one frame's separation
    let t_show = FRAMES / 2;
    println!("\n  frame {t_show}: observed / recovered background / |sparse| (downsampled)");
    for y in (0..H).step_by(4) {
        let mut obs = String::new();
        let mut bg = String::new();
        let mut fg = String::new();
        for x in (0..W).step_by(2) {
            let px = y * W + x;
            obs.push(shade(video[(px, t_show)]));
            bg.push(shade(result.l[(px, t_show)]));
            fg.push(if result.s[(px, t_show)].abs() > 30.0 { '#' } else { '.' });
        }
        println!("  {obs}   {bg}   {fg}");
    }

    dcf_pca::ensure!(f1 > 0.9, "foreground F1 too low: {f1}");
    Ok(())
}

fn shade(v: f64) -> char {
    match v as i64 {
        i64::MIN..=49 => ' ',
        50..=69 => '.',
        70..=89 => ':',
        90..=119 => 'o',
        _ => '@',
    }
}
