#!/usr/bin/env bash
# Run the kernel hot-path bench and diff its per-kernel rates against the
# checked-in baseline, so perf regressions show up as a review comment
# instead of a silent drift.
#
# Usage: scripts/bench_trend.sh [extra cargo-bench args...]
#
#   - runs `cargo bench --bench kernel_hotpath`, which rewrites
#     BENCH_kernel_hotpath.json ({host, records});
#   - if BENCH_kernel_hotpath.baseline.json does not exist yet, seeds it
#     from this run (commit it from the machine the trend should track —
#     baselines are per-host, the header records which one);
#   - otherwise prints a per-(op, shape) GFLOP/s delta table and exits
#     non-zero if any kernel regressed more than $TREND_TOLERANCE
#     (default 20%, generous because shared CI boxes are noisy).

set -euo pipefail
cd "$(dirname "$0")/.."

CURRENT=BENCH_kernel_hotpath.json
BASELINE=BENCH_kernel_hotpath.baseline.json
TOLERANCE="${TREND_TOLERANCE:-0.20}"

cargo bench --bench kernel_hotpath "$@"

if [[ ! -f "$CURRENT" ]]; then
    echo "error: bench did not produce $CURRENT" >&2
    exit 1
fi

if [[ ! -f "$BASELINE" ]]; then
    cp "$CURRENT" "$BASELINE"
    echo
    echo "No baseline found — seeded $BASELINE from this run."
    echo "Commit it from the hardware the trend should track:"
    echo "    git add $BASELINE"
    exit 0
fi

python3 - "$BASELINE" "$CURRENT" "$TOLERANCE" <<'EOF'
import json
import sys

base_path, cur_path, tol_s = sys.argv[1], sys.argv[2], sys.argv[3]
tol = float(tol_s)

def load(path):
    with open(path) as f:
        doc = json.load(f)
    # pre-PR-6 files were a bare record array
    records = doc["records"] if isinstance(doc, dict) else doc
    host = doc.get("host", {}) if isinstance(doc, dict) else {}
    return host, {
        (r["op"], r["shape"]): r["gflops"]
        for r in records
        if r.get("gflops") is not None
    }

bhost, base = load(base_path)
chost, cur = load(cur_path)

if bhost.get("dispatch") != chost.get("dispatch"):
    print(
        f"note: dispatch changed {bhost.get('dispatch')} -> "
        f"{chost.get('dispatch')} — deltas compare different code paths"
    )

rows, regressions = [], []
for key in sorted(base):
    if key not in cur:
        continue
    b, c = base[key], cur[key]
    delta = (c - b) / b if b else 0.0
    rows.append((key, b, c, delta))
    if delta < -tol:
        regressions.append((key, b, c, delta))

w = max((len(f"{op} {shape}") for (op, shape), *_ in rows), default=20)
print(f"\n{'kernel':<{w}}  {'base':>9}  {'now':>9}  {'delta':>8}")
for (op, shape), b, c, delta in rows:
    print(f"{op + ' ' + shape:<{w}}  {b:>9.2f}  {c:>9.2f}  {delta:>+7.1%}")

new_keys = sorted(set(cur) - set(base))
if new_keys:
    print(f"\n{len(new_keys)} kernel(s) not in baseline (re-seed to track):")
    for op, shape in new_keys:
        print(f"  {op} {shape}")

if regressions:
    print(f"\nFAIL: {len(regressions)} kernel(s) regressed more than {tol:.0%}:")
    for (op, shape), b, c, delta in regressions:
        print(f"  {op} {shape}: {b:.2f} -> {c:.2f} GFLOP/s ({delta:+.1%})")
    sys.exit(1)
print(f"\nOK: no kernel regressed more than {tol:.0%}")
EOF
