#!/usr/bin/env bash
# Run the perf benches and diff their records against the checked-in
# baselines, so perf regressions show up as a review comment instead of
# a silent drift.
#
# Usage: scripts/bench_trend.sh [extra cargo-bench args...]
#
#   - runs `cargo bench --bench kernel_hotpath` (rewrites
#     BENCH_kernel_hotpath.json) and `cargo bench --bench comm_scaling`
#     (rewrites BENCH_comm_scaling.json), both `{host, records}`;
#   - for each file: if its `.baseline.json` twin does not exist yet,
#     seeds it from this run (commit it from the machine the trend
#     should track — baselines are per-host, the header records which);
#   - otherwise prints a per-(op, shape) delta table and exits non-zero
#     if any record regressed more than $TREND_TOLERANCE (default 20%,
#     generous because shared CI boxes are noisy). Kernel records are
#     GFLOP/s rates (higher is better); comm records carry an explicit
#     `better` direction (ingest bytes and latencies regress upward).

set -euo pipefail
cd "$(dirname "$0")/.."

TOLERANCE="${TREND_TOLERANCE:-0.20}"
STATUS=0

cargo bench --bench kernel_hotpath "$@"
cargo bench --bench comm_scaling "$@"

# BENCH_service.json is produced by `dcf-pca loadgen` against a live
# `serve --service` (the CI service-soak job, or a manual run) — trend
# it when present rather than re-running a whole service here.
FILES=(BENCH_kernel_hotpath.json BENCH_comm_scaling.json)
[[ -f BENCH_service.json ]] && FILES+=(BENCH_service.json)

for CURRENT in "${FILES[@]}"; do
    BASELINE="${CURRENT%.json}.baseline.json"

    if [[ ! -f "$CURRENT" ]]; then
        echo "error: bench did not produce $CURRENT" >&2
        exit 1
    fi

    if [[ ! -f "$BASELINE" ]]; then
        cp "$CURRENT" "$BASELINE"
        echo
        echo "No baseline found — seeded $BASELINE from this run."
        echo "Commit it from the hardware the trend should track:"
        echo "    git add $BASELINE"
        continue
    fi

    echo
    echo "== trend: $CURRENT vs $BASELINE =="
    python3 - "$BASELINE" "$CURRENT" "$TOLERANCE" <<'EOF' || STATUS=1
import json
import sys

base_path, cur_path, tol_s = sys.argv[1], sys.argv[2], sys.argv[3]
tol = float(tol_s)


# measures this script knows how to trend, in pick order, with the
# direction assumed when a record carries no explicit `better`. Records
# gain fields across PRs (bytes_per_round, compression_ratio, ...);
# unknown extras are ignored and unknown record shapes are skipped, so
# schema growth never breaks the diff.
VALUE_FIELDS = (
    ("gflops", "higher"),
    ("value", "lower"),
    ("bytes_per_round", "lower"),
    ("compression_ratio", "higher"),
)


def pick(r):
    for field, default_better in VALUE_FIELDS:
        v = r.get(field)
        if isinstance(v, (int, float)):
            return v, r.get("better", default_better)
    return None


def load(path):
    with open(path) as f:
        doc = json.load(f)
    # pre-PR-6 files were a bare record array
    records = doc["records"] if isinstance(doc, dict) else doc
    host = doc.get("host", {}) if isinstance(doc, dict) else {}
    out = {}
    for r in records:
        op, shape, picked = r.get("op"), r.get("shape"), pick(r)
        if op is None or shape is None or picked is None:
            continue
        out[(op, shape)] = picked
    return host, out


bhost, base = load(base_path)
chost, cur = load(cur_path)

if bhost.get("dispatch") != chost.get("dispatch"):
    print(
        f"note: dispatch changed {bhost.get('dispatch')} -> "
        f"{chost.get('dispatch')} — deltas compare different code paths"
    )

rows, regressions = [], []
for key in sorted(base):
    if key not in cur:
        continue
    (b, better), (c, _) = base[key], cur[key]
    delta = (c - b) / b if b else 0.0
    rows.append((key, b, c, delta))
    regressed = delta < -tol if better == "higher" else delta > tol
    if regressed:
        regressions.append((key, b, c, delta))

w = max((len(f"{op} {shape}") for (op, shape), *_ in rows), default=20)
print(f"\n{'record':<{w}}  {'base':>12}  {'now':>12}  {'delta':>8}")
for (op, shape), b, c, delta in rows:
    print(f"{op + ' ' + shape:<{w}}  {b:>12.2f}  {c:>12.2f}  {delta:>+7.1%}")

new_keys = sorted(set(cur) - set(base))
if new_keys:
    print(f"\n{len(new_keys)} record(s) not in baseline (re-seed to track):")
    for op, shape in new_keys:
        print(f"  {op} {shape}")

gone = sorted(set(base) - set(cur))
if gone:
    print(f"\n{len(gone)} baseline record(s) absent from this run (renamed or removed — re-seed):")
    for op, shape in gone:
        print(f"  {op} {shape}")

if regressions:
    print(f"\nFAIL: {len(regressions)} record(s) regressed more than {tol:.0%}:")
    for (op, shape), b, c, delta in regressions:
        print(f"  {op} {shape}: {b:.2f} -> {c:.2f} ({delta:+.1%})")
    sys.exit(1)
print(f"\nOK: no record regressed more than {tol:.0%}")
EOF
done

exit "$STATUS"
