"""L2 — the DCF-PCA client local update as a JAX computation.

`client_update` is Algorithm 1's per-client epoch: K local iterations of
{J inner sweeps (Eqs. 15+16 via the Pallas kernels), one gradient step on
U (Eq. 8)}. It is lowered ONCE per shape variant by `aot.py` to HLO text
and executed from rust through PJRT; python never runs at serving time.

The r×r ridge solve stays in jnp (jnp.linalg.solve): it is O(r³ + r²n_i)
against the kernels' O(m·n_i·r), and XLA fuses it into the surrounding
graph. Everything m-sized goes through the L1 Pallas kernels.
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import gram_rhs, residual_shrink, u_grad


def cholesky_solve_unrolled(a, b):
    """Solve A·X = B for SPD A (r×r) with B (r×n) — statically unrolled.

    `jnp.linalg.solve` lowers to a LAPACK typed-FFI custom call that the
    crate's xla_extension 0.5.1 cannot execute, so the r×r solve is
    spelled out as scalar HLO ops (r is a small static constant — ≤ a few
    dozen in every variant). No pivoting needed: A = G + ρI is SPD.
    """
    r = a.shape[0]
    # Cholesky factor as a grid of scalar expressions
    l = [[None] * r for _ in range(r)]
    for j in range(r):
        d = a[j, j] - sum((l[j][k] * l[j][k] for k in range(j)), start=jnp.float32(0.0))
        ljj = jnp.sqrt(d)
        l[j][j] = ljj
        for i in range(j + 1, r):
            s = a[i, j] - sum((l[i][k] * l[j][k] for k in range(j)), start=jnp.float32(0.0))
            l[i][j] = s / ljj
    # forward substitution L·Y = B (row vectors of length n)
    y = [None] * r
    for i in range(r):
        acc = b[i, :]
        for k in range(i):
            acc = acc - l[i][k] * y[k]
        y[i] = acc / l[i][i]
    # backward substitution Lᵀ·X = Y
    x = [None] * r
    for i in reversed(range(r)):
        acc = y[i]
        for k in range(i + 1, r):
            acc = acc - l[k][i] * x[k]
        x[i] = acc / l[i][i]
    return jnp.stack(x, axis=0)  # (r, n)


def inner_sweep(u, v, s, m, *, rho, lam, block_m):
    """One exact alternation of the inner problem (Eqs. 15 + 16)."""
    del v  # the V update is exact given S; the old V is not needed
    g, rhs = gram_rhs(u, m - s, block_m=block_m)
    r = g.shape[0]
    vt = cholesky_solve_unrolled(g + rho * jnp.eye(r, dtype=g.dtype), rhs)
    v = vt.T
    s = residual_shrink(u, v, m, lam, block_m=block_m)
    return v, s


@functools.partial(
    jax.jit, static_argnames=("k_local", "inner_sweeps", "rho", "lam", "block_m")
)
def client_update(u, s, m, eta, n_frac, *, k_local, inner_sweeps, rho, lam, block_m):
    """K local iterations; returns (U', V', S', ‖∇_U‖_F at the last step).

    Shapes: u (m,p) f32, s/m (m,n_i) f32, eta/n_frac f32 scalars. There is
    deliberately NO V input: with J ≥ 1 the first exact sweep (Eq. 15)
    recomputes V from (U, S), so a V argument would be dead — and JAX's
    lowering DCEs dead parameters out of the HLO signature, which would
    desynchronize the rust caller. Only S carries client state across
    rounds (matching the native kernel, whose first sweep also discards V).

    K and J are unrolled (they are 1–10 in every experiment and unrolling
    lets XLA fuse across iterations; `lax.scan` would block the
    gram_rhs/solve fusion at each boundary for no memory win — the carry
    is the whole state either way).
    """
    assert inner_sweeps >= 1, "J = 0 would make V genuinely stateful"
    grad_norm = jnp.zeros((), dtype=jnp.float32)
    n_i = m.shape[1]
    v = jnp.zeros((n_i, u.shape[1]), dtype=jnp.float32)
    for _ in range(k_local):
        for _ in range(inner_sweeps):
            v, s = inner_sweep(u, v, s, m, rho=rho, lam=lam, block_m=block_m)
        grad = u_grad(u, v, s, m, rho * n_frac, block_m=block_m)
        grad_norm = jnp.sqrt(jnp.sum(grad * grad))
        u = u - eta * grad
    return u, v, s, grad_norm


def build_for_variant(variant, baked):
    """Bind a variant's static parameters; returns (fn, example_args)."""
    from . import shapes

    m, n_i, r = variant["m"], variant["n_i"], variant["r"]
    bm = shapes.block_m(m)
    fn = functools.partial(
        client_update,
        k_local=variant["k_local"],
        inner_sweeps=variant["inner_sweeps"],
        rho=baked["rho"],
        lam=shapes.lam_for(r),
        block_m=bm,
    )
    example = (
        jax.ShapeDtypeStruct((m, r), jnp.float32),  # u
        jax.ShapeDtypeStruct((m, n_i), jnp.float32),  # s
        jax.ShapeDtypeStruct((m, n_i), jnp.float32),  # m block
        jax.ShapeDtypeStruct((), jnp.float32),  # eta
        jax.ShapeDtypeStruct((), jnp.float32),  # n_frac
    )
    return fn, example
