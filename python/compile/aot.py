"""AOT lowering: JAX/Pallas `client_update` → HLO text artifacts.

Interchange format is HLO **text**, not serialized HloModuleProto — the
image's xla_extension 0.5.1 rejects jax≥0.5 protos whose instruction ids
exceed INT_MAX; the text parser reassigns ids and round-trips cleanly
(see /opt/xla-example/README.md and DESIGN.md §Substitutions).

Usage:  python -m compile.aot --out-dir ../artifacts
Writes one `<variant>.hlo.txt` per entry in shapes.VARIANTS plus
`manifest.json` (consumed by rust/src/runtime/artifacts.rs).
"""

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model, shapes


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-reassigning path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_variant(variant) -> str:
    fn, example = model.build_for_variant(variant, shapes.BAKED)
    lowered = jax.jit(fn).lower(*example)
    return to_hlo_text(lowered)


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts", help="artifact output directory")
    parser.add_argument(
        "--only", default=None, help="lower just the variant with this name (debugging)"
    )
    args = parser.parse_args()
    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"version": 1, "dtype": "f32", "baked": shapes.BAKED, "variants": []}
    for variant in shapes.VARIANTS:
        name = shapes.variant_name(variant)
        if args.only and name != args.only:
            continue
        text = lower_variant(variant)
        fname = f"{name}.hlo.txt"
        (out_dir / fname).write_text(text)
        manifest["variants"].append({"file": fname, **variant})
        print(f"  lowered {name}: {len(text)} chars")
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"wrote {len(manifest['variants'])} artifact(s) + manifest to {out_dir}")


if __name__ == "__main__":
    main()
