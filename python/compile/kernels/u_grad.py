"""Pallas kernel: fused U-gradient (paper Eq. 8 / Lemma 2).

∇_U L_i = (U Vᵀ + S − M)·V + ρ·(n_i/n)·U, tiled over m. Each grid step
*re-materializes* its bm×n_i residual tile on the MXU and immediately
contracts it with V — two chained MXU ops per tile, no HBM round-trip
for the residual (rematerialize > spill: the residual is m×n_i while
U-tile and V are tiny).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _u_grad_kernel(rho_ref, u_ref, v_ref, s_ref, m_ref, g_ref):
    u_blk = u_ref[...]  # (bm, r)
    v_all = v_ref[...]  # (n_i, r)
    s_blk = s_ref[...]  # (bm, n_i)
    m_blk = m_ref[...]  # (bm, n_i)
    rho_nfrac = rho_ref[0]
    uv = jax.lax.dot_general(
        u_blk, v_all, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )
    resid = uv + s_blk - m_blk  # (bm, n_i)
    g_ref[...] = (
        jax.lax.dot_general(
            resid, v_all, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        + rho_nfrac * u_blk
    )


@functools.partial(jax.jit, static_argnames=("block_m",))
def u_grad(u, v, s, m, rho_nfrac, *, block_m):
    """∇_U L_i. u:(m,r), v:(n_i,r), s,m:(m,n_i), rho_nfrac scalar."""
    mm, r = u.shape
    n_i, _ = v.shape
    assert mm % block_m == 0
    rho_arr = jnp.asarray(rho_nfrac, dtype=jnp.float32).reshape((1,))
    grid = (mm // block_m,)
    return pl.pallas_call(
        _u_grad_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_m, r), lambda i: (i, 0)),
            pl.BlockSpec((n_i, r), lambda i: (0, 0)),
            pl.BlockSpec((block_m, n_i), lambda i: (i, 0)),
            pl.BlockSpec((block_m, n_i), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mm, r), jnp.float32),
        interpret=True,
    )(rho_arr, u, v, s, m)
