"""L1 — Pallas kernels for the DCF-PCA client local update.

Three fused kernels cover the inner loop's hot spots (all interpret=True
for CPU-PJRT executability; see DESIGN.md section Hardware-Adaptation for
the TPU tiling rationale):

- gram_rhs:         G = U^T U, R = U^T (M-S)   (one pass over m)
- residual_shrink:  S = shrink_lam(M - U V^T)  (residual never hits HBM)
- u_grad:           (U V^T + S - M)V + rho' U  (residual rematerialized)

`ref` holds the pure-jnp oracles the kernels are tested against.
"""

from .gram_rhs import gram_rhs
from .residual_shrink import residual_shrink
from .u_grad import u_grad

__all__ = ["gram_rhs", "residual_shrink", "u_grad"]
