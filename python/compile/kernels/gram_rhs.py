"""Pallas kernel: fused Gram + RHS for the ridge solve (paper Eq. 15).

Computes G = UᵀU (r×r) and R = Uᵀ(M−S) (r×n_i) in ONE pass over the
m dimension: grid over m-tiles, both products accumulated in the output
refs (which live in VMEM for the whole grid — the classic TPU reduction
tiling). On real hardware this reads U and (M−S) from HBM exactly once;
the two MXU contractions share the U tile already resident in VMEM.

VMEM budget per grid step (f32): bm·r (U tile) + bm·n_i (MS tile)
+ r·r + r·n_i (accumulators) — with bm ≤ 64, n_i ≤ 512, r ≤ 64 this is
well under the ~16 MiB/core VMEM of a TPUv4 (see DESIGN.md §Perf).

interpret=True everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; lowering stays structurally identical.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gram_rhs_kernel(u_ref, ms_ref, g_ref, r_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        r_ref[...] = jnp.zeros_like(r_ref)

    u_blk = u_ref[...]  # (bm, r)
    ms_blk = ms_ref[...]  # (bm, n_i)
    # MXU contractions over the m-tile; accumulate in f32
    g_ref[...] += jax.lax.dot_general(
        u_blk, u_blk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    r_ref[...] += jax.lax.dot_general(
        u_blk, ms_blk, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("block_m",))
def gram_rhs(u, ms, *, block_m):
    """G = UᵀU, R = Uᵀ·ms. `u` is (m, r), `ms` is (m, n_i)."""
    m, r = u.shape
    _, n_i = ms.shape
    assert m % block_m == 0, f"m={m} must be divisible by block_m={block_m}"
    grid = (m // block_m,)
    return pl.pallas_call(
        _gram_rhs_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_m, r), lambda i: (i, 0)),
            pl.BlockSpec((block_m, n_i), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((r, r), lambda i: (0, 0)),
            pl.BlockSpec((r, n_i), lambda i: (0, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((r, r), jnp.float32),
            jax.ShapeDtypeStruct((r, n_i), jnp.float32),
        ),
        interpret=True,
    )(u, ms)
