"""Pallas kernel: fused residual + soft threshold (paper Eq. 16).

S = shrink_λ(M − U Vᵀ), tiled over m: each grid step computes one
bm×n_i residual tile on the MXU (U tile × Vᵀ, V resident in VMEM across
the whole grid) and applies the shrinkage on the VPU — the m×n_i
residual is never materialized in HBM, which is the point of the fusion:
the paper's inner loop is bandwidth-bound and this kernel reads M once
and writes S once.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _residual_shrink_kernel(lam_ref, u_ref, v_ref, m_ref, s_ref):
    u_blk = u_ref[...]  # (bm, r)
    v_all = v_ref[...]  # (n_i, r) — broadcast over the grid
    m_blk = m_ref[...]  # (bm, n_i)
    lam = lam_ref[0]
    uv = jax.lax.dot_general(
        u_blk, v_all, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (bm, n_i)
    resid = m_blk - uv
    s_ref[...] = jnp.sign(resid) * jnp.maximum(jnp.abs(resid) - lam, 0.0)


@functools.partial(jax.jit, static_argnames=("block_m",))
def residual_shrink(u, v, m, lam, *, block_m):
    """S = shrink_λ(M − U Vᵀ). u:(m,r), v:(n_i,r), m:(m,n_i), lam scalar."""
    mm, r = u.shape
    n_i, _ = v.shape
    assert mm % block_m == 0
    lam_arr = jnp.asarray(lam, dtype=jnp.float32).reshape((1,))
    grid = (mm // block_m,)
    return pl.pallas_call(
        _residual_shrink_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block_m, r), lambda i: (i, 0)),
            pl.BlockSpec((n_i, r), lambda i: (0, 0)),
            pl.BlockSpec((block_m, n_i), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((block_m, n_i), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mm, n_i), jnp.float32),
        interpret=True,
    )(lam_arr, u, v, m)
