"""Pure-jnp oracle for the Pallas kernels and the full client update.

This is the CORE correctness reference on the python side: every Pallas
kernel is asserted allclose against these functions in
python/tests/test_kernels.py, and the full `client_update` in model.py is
asserted against `client_update_ref`. The rust NativeKernel implements
the same math in f64 (rust/src/algorithms/factor.rs); the three
implementations are pinned together by the parity tests.
"""

import jax.numpy as jnp


def gram_rhs_ref(u, ms):
    """G = UᵀU (r×r), R = Uᵀ·(M−S) (r×n_i). `ms` is the matrix M−S."""
    g = u.T @ u
    r = u.T @ ms
    return g, r


def residual_shrink_ref(u, v, m, lam):
    """S = shrink_λ(M − U Vᵀ) — paper Eq. 16."""
    resid = m - u @ v.T
    return jnp.sign(resid) * jnp.maximum(jnp.abs(resid) - lam, 0.0)


def u_grad_ref(u, v, s, m, rho_nfrac):
    """∇_U L_i = (U Vᵀ + S − M) V + ρ·(n_i/n)·U — paper Lemma 2."""
    resid = u @ v.T + s - m
    return resid @ v + rho_nfrac * u


def ridge_solve_ref(g, rhs, rho):
    """V = ((G + ρI)^{-1} RHS)ᵀ — paper Eq. 15 (RHS is r×n_i)."""
    r = g.shape[0]
    vt = jnp.linalg.solve(g + rho * jnp.eye(r, dtype=g.dtype), rhs)
    return vt.T


def inner_sweep_ref(u, v, s, m, rho, lam):
    """One exact alternation of the inner problem (Eqs. 15 + 16)."""
    g, rhs = gram_rhs_ref(u, m - s)
    v = ridge_solve_ref(g, rhs, rho)
    s = residual_shrink_ref(u, v, m, lam)
    return v, s


def client_update_ref(u, s, m, eta, n_frac, *, k_local, inner_sweeps, rho, lam):
    """K local iterations: J inner sweeps then one U gradient step each.

    Returns (U', V', S', ‖∇_U‖_F at the last step). Mirrors
    NativeKernel::local_epoch in rust/src/coordinator/kernel.rs. No V
    input: the first exact sweep recomputes it (see model.client_update).
    """
    grad_norm = jnp.zeros((), dtype=u.dtype)
    v = jnp.zeros((m.shape[1], u.shape[1]), dtype=u.dtype)
    for _ in range(k_local):
        for _ in range(inner_sweeps):
            v, s = inner_sweep_ref(u, v, s, m, rho, lam)
        grad = u_grad_ref(u, v, s, m, rho * n_frac)
        grad_norm = jnp.sqrt(jnp.sum(grad * grad))
        u = u - eta * grad
    return u, v, s, grad_norm
