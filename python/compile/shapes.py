"""Artifact shape variants and baked hyperparameters.

Single source of truth shared by `aot.py` (what to lower) and the rust
runtime (`rust/src/runtime/executor.rs` BakedHyper must match BAKED).

Each variant fixes (m, n_i, r, K, J) at lowering time; the rust
coordinator zero-pads client blocks up to the variant's n_i (padding
safety is tested on both sides). Block sizes for the Pallas m-tiling are
chosen per variant as the largest divisor of m ≤ 64.
"""

# keep in sync with rust/src/runtime/executor.rs::BakedHyper::default()
BAKED = {
    "rho": 1e-2,
    # lambda = lambda_scale * sqrt(r)
    "lambda_scale": 1.0,
}

# (m, n_i, r, k_local, inner_sweeps)
VARIANTS = [
    # parity-test scale
    dict(m=40, n_i=40, r=2, k_local=1, inner_sweeps=3),
    dict(m=40, n_i=40, r=2, k_local=2, inner_sweeps=3),
    # e2e example: n=60, E=5 → blocks of 12 columns
    dict(m=60, n_i=12, r=3, k_local=2, inner_sweeps=3),
    # a mid-size block with uneven-width headroom (pads 17..32)
    dict(m=64, n_i=32, r=4, k_local=2, inner_sweeps=3),
    # wider aspect, K=5 (fig4-style ablation through the artifact path)
    dict(m=60, n_i=30, r=3, k_local=5, inner_sweeps=3),
]


def lam_for(r: int) -> float:
    """λ = lambda_scale·√r (matches FactorHyper::default_for in rust)."""
    return BAKED["lambda_scale"] * max(float(r) ** 0.5, 1.0)


def block_m(m: int, cap: int = 64) -> int:
    """Largest divisor of m that is ≤ cap — the Pallas m-tile height."""
    best = 1
    for d in range(1, min(m, cap) + 1):
        if m % d == 0:
            best = d
    return best


def variant_name(v: dict) -> str:
    return "client_m{m}_n{n_i}_r{r}_k{k_local}_j{inner_sweeps}".format(**v)
