"""AOT path smoke tests: lowering produces parseable HLO text and a
manifest the rust side can consume."""

import json
import subprocess
import sys
import pathlib

import pytest

from compile import aot, shapes


def test_variant_names_unique():
    names = [shapes.variant_name(v) for v in shapes.VARIANTS]
    assert len(names) == len(set(names))


def test_block_m_divides():
    for v in shapes.VARIANTS:
        bm = shapes.block_m(v["m"])
        assert v["m"] % bm == 0
        assert 1 <= bm <= 64
    assert shapes.block_m(64) == 64
    assert shapes.block_m(60) == 60
    assert shapes.block_m(97) == 1  # prime > cap


def test_lam_matches_rust_default():
    # rust FactorHyper::default_for: λ = max(√r, 1)
    assert shapes.lam_for(4) == pytest.approx(2.0)
    assert shapes.lam_for(1) == pytest.approx(1.0)


def test_lowering_smallest_variant_produces_hlo_text():
    variant = dict(m=8, n_i=4, r=2, k_local=1, inner_sweeps=1)
    text = aot.lower_variant(variant)
    assert "HloModule" in text
    # the tuple return: 4 outputs
    assert "tuple" in text
    # pallas (interpret mode) lowers to plain HLO — no Mosaic custom-call
    assert "mosaic" not in text.lower()


def test_aot_main_writes_manifest(tmp_path):
    """Run the module CLI end-to-end for one variant."""
    name = shapes.variant_name(shapes.VARIANTS[0])
    proc = subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(tmp_path),
            "--only",
            name,
        ],
        cwd=str(pathlib.Path(__file__).resolve().parents[1]),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert proc.returncode == 0, proc.stderr
    manifest = json.loads((tmp_path / "manifest.json").read_text())
    assert manifest["version"] == 1
    assert manifest["dtype"] == "f32"
    assert len(manifest["variants"]) == 1
    v = manifest["variants"][0]
    assert v["file"] == f"{name}.hlo.txt"
    assert (tmp_path / v["file"]).exists()
    for key in ("m", "n_i", "r", "k_local", "inner_sweeps"):
        assert key in v
