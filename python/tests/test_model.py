"""L2 correctness: the full client_update vs the pure-jnp reference, plus
the semantic properties the coordinator relies on (K-composition,
padding safety, descent)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile import shapes
from compile.kernels.ref import client_update_ref
from compile.model import client_update

RHO = shapes.BAKED["rho"]


def rand(key, shape, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def make_problem(seed, m, n_i, r):
    """Small synthetic block: low rank + sparse spikes."""
    u0 = rand(seed, (m, r))
    v0 = rand(seed + 1, (n_i, r))
    l0 = u0 @ v0.T
    key = jax.random.PRNGKey(seed + 2)
    mask = jax.random.bernoulli(key, 0.05, (m, n_i)).astype(jnp.float32)
    spikes = mask * jnp.float32(np.sqrt(m * n_i))
    return l0 + spikes


@hypothesis.settings(deadline=None, max_examples=10)
@hypothesis.given(
    m=st.sampled_from([8, 16, 24]),
    n_i=st.sampled_from([6, 12]),
    r=st.integers(1, 3),
    k_local=st.integers(1, 3),
    seed=st.integers(0, 1000),
)
def test_client_update_matches_ref(m, n_i, r, k_local, seed):
    lam = shapes.lam_for(r)
    bm = shapes.block_m(m, cap=16)
    mat = make_problem(seed, m, n_i, r)
    u = rand(seed + 10, (m, r))
    v = jnp.zeros((n_i, r), dtype=jnp.float32)
    s = jnp.zeros((m, n_i), dtype=jnp.float32)
    eta = jnp.float32(1e-3)
    n_frac = jnp.float32(0.5)
    got = client_update(
        u, s, mat, eta, n_frac,
        k_local=k_local, inner_sweeps=3, rho=RHO, lam=lam, block_m=bm,
    )
    want = client_update_ref(
        u, s, mat, eta, n_frac,
        k_local=k_local, inner_sweeps=3, rho=RHO, lam=lam,
    )
    for g, w, name in zip(got, want, ["u", "v", "s", "grad_norm"]):
        np.testing.assert_allclose(g, w, rtol=5e-4, atol=5e-4, err_msg=name)


def test_k_steps_compose():
    """One K=2 epoch equals two chained K=1 epochs."""
    m, n_i, r = 16, 8, 2
    lam = shapes.lam_for(r)
    mat = make_problem(3, m, n_i, r)
    u = rand(4, (m, r))
    v = jnp.zeros((n_i, r), dtype=jnp.float32)
    s = jnp.zeros((m, n_i), dtype=jnp.float32)
    kw = dict(inner_sweeps=3, rho=RHO, lam=lam, block_m=8)
    eta, n_frac = jnp.float32(1e-3), jnp.float32(1.0)

    u2, v2, s2, _ = client_update(u, s, mat, eta, n_frac, k_local=2, **kw)
    ua, va, sa, _ = client_update(u, s, mat, eta, n_frac, k_local=1, **kw)
    ub, vb, sb, _ = client_update(ua, sa, mat, eta, n_frac, k_local=1, **kw)
    np.testing.assert_allclose(u2, ub, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v2, vb, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s2, sb, rtol=1e-5, atol=1e-5)


def test_padding_safety():
    """Zero-padding M's columns must not change U' or the real V/S parts.

    This is the property the rust executor's shape-variant dispatch
    relies on (runtime/executor.rs pads client blocks to the artifact's
    n_i).
    """
    m, n_real, n_pad, r = 16, 6, 10, 2
    lam = shapes.lam_for(r)
    mat = make_problem(5, m, n_real, r)
    mat_padded = jnp.pad(mat, ((0, 0), (0, n_pad - n_real)))
    u = rand(6, (m, r))
    kw = dict(k_local=2, inner_sweeps=3, rho=RHO, lam=lam, block_m=8)
    eta, n_frac = jnp.float32(1e-3), jnp.float32(0.25)

    u_a, v_a, s_a, gn_a = client_update(
        u, jnp.zeros((m, n_real), jnp.float32), mat, eta, n_frac, **kw,
    )
    u_b, v_b, s_b, gn_b = client_update(
        u, jnp.zeros((m, n_pad), jnp.float32), mat_padded, eta, n_frac, **kw,
    )
    np.testing.assert_allclose(u_b, u_a, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(v_b[:n_real], v_a, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(s_b[:, :n_real], s_a, rtol=1e-5, atol=1e-5)
    # padded region stays exactly zero
    assert np.all(np.asarray(v_b[n_real:]) == 0.0)
    assert np.all(np.asarray(s_b[:, n_real:]) == 0.0)
    np.testing.assert_allclose(gn_b, gn_a, rtol=1e-4, atol=1e-5)


def test_epoch_descends_inner_objective():
    """A local epoch with a small η must not increase the local objective."""
    m, n_i, r = 24, 12, 2
    lam = shapes.lam_for(r)
    mat = make_problem(7, m, n_i, r)
    u = rand(8, (m, r))
    v = jnp.zeros((n_i, r), dtype=jnp.float32)
    s = jnp.zeros((m, n_i), dtype=jnp.float32)

    def objective(u, v, s):
        fit = u @ v.T + s - mat
        return (
            0.5 * jnp.sum(fit * fit)
            + 0.5 * RHO * jnp.sum(v * v)
            + lam * jnp.sum(jnp.abs(s))
            + 0.5 * RHO * jnp.sum(u * u)
        )

    kw = dict(k_local=1, inner_sweeps=5, rho=RHO, lam=lam, block_m=8)
    u1, v1, s1, _ = client_update(u, s, mat, jnp.float32(1e-4), jnp.float32(1.0), **kw)
    # compare objectives at the *solved* (v,s) for each u
    obj0 = objective(u, v1, s1)  # upper bounds g(u) at the solved point
    u2, v2, s2, _ = client_update(u1, s1, mat, jnp.float32(1e-4), jnp.float32(1.0), **kw)
    obj1 = objective(u1, v2, s2)
    assert float(obj1) <= float(obj0) * (1 + 1e-5)


def test_grad_norm_is_positive_and_finite():
    m, n_i, r = 16, 8, 2
    mat = make_problem(9, m, n_i, r)
    u = rand(10, (m, r))
    out = client_update(
        u,
        jnp.zeros((m, n_i), jnp.float32),
        mat,
        jnp.float32(1e-3),
        jnp.float32(1.0),
        k_local=1,
        inner_sweeps=3,
        rho=RHO,
        lam=shapes.lam_for(r),
        block_m=8,
    )
    gn = float(out[3])
    assert np.isfinite(gn) and gn > 0
