"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes (and the λ/ρ scalars); interpret=True keeps the
kernels executable on CPU. Tolerances are f32-scale.
"""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import shapes
from compile.kernels import gram_rhs, residual_shrink, u_grad
from compile.kernels.ref import (
    gram_rhs_ref,
    residual_shrink_ref,
    ridge_solve_ref,
    u_grad_ref,
)

# shared hypothesis config: interpret-mode pallas is slow → keep cases small
COMMON = dict(deadline=None, max_examples=20)


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype=jnp.float32)


def divisors_block(m):
    return shapes.block_m(m, cap=32)


@hypothesis.settings(**COMMON)
@hypothesis.given(
    m=st.integers(4, 48),
    n_i=st.integers(1, 24),
    r=st.integers(1, 6),
    seed=st.integers(0, 2**16),
)
def test_gram_rhs_matches_ref(m, n_i, r, seed):
    bm = divisors_block(m)
    u = rand(seed, (m, r))
    ms = rand(seed + 1, (m, n_i))
    g, rhs = gram_rhs(u, ms, block_m=bm)
    g_ref, rhs_ref = gram_rhs_ref(u, ms)
    np.testing.assert_allclose(g, g_ref, rtol=2e-5, atol=1e-5)
    np.testing.assert_allclose(rhs, rhs_ref, rtol=2e-5, atol=1e-5)


@hypothesis.settings(**COMMON)
@hypothesis.given(
    m=st.integers(4, 48),
    n_i=st.integers(1, 24),
    r=st.integers(1, 6),
    lam=st.floats(0.0, 5.0),
    seed=st.integers(0, 2**16),
)
def test_residual_shrink_matches_ref(m, n_i, r, lam, seed):
    bm = divisors_block(m)
    u = rand(seed, (m, r))
    v = rand(seed + 1, (n_i, r))
    mat = 3.0 * rand(seed + 2, (m, n_i))
    s = residual_shrink(u, v, mat, lam, block_m=bm)
    s_ref = residual_shrink_ref(u, v, mat, jnp.float32(lam))
    np.testing.assert_allclose(s, s_ref, rtol=2e-5, atol=1e-5)


@hypothesis.settings(**COMMON)
@hypothesis.given(
    m=st.integers(4, 48),
    n_i=st.integers(1, 24),
    r=st.integers(1, 6),
    rho_nfrac=st.floats(0.0, 1.0),
    seed=st.integers(0, 2**16),
)
def test_u_grad_matches_ref(m, n_i, r, rho_nfrac, seed):
    bm = divisors_block(m)
    u = rand(seed, (m, r))
    v = rand(seed + 1, (n_i, r))
    s = rand(seed + 2, (m, n_i))
    mat = rand(seed + 3, (m, n_i))
    g = u_grad(u, v, s, mat, rho_nfrac, block_m=bm)
    g_ref = u_grad_ref(u, v, s, mat, jnp.float32(rho_nfrac))
    np.testing.assert_allclose(g, g_ref, rtol=3e-5, atol=3e-5)


@pytest.mark.parametrize("bm", [1, 2, 4, 8, 16])
def test_tiling_invariance(bm):
    """Different m-tile heights must give identical results."""
    m, n_i, r = 16, 10, 3
    u = rand(0, (m, r))
    v = rand(1, (n_i, r))
    mat = rand(2, (m, n_i))
    base = residual_shrink(u, v, mat, 0.5, block_m=16)
    tiled = residual_shrink(u, v, mat, 0.5, block_m=bm)
    np.testing.assert_allclose(tiled, base, rtol=1e-6, atol=1e-6)
    g16, r16 = gram_rhs(u, mat, block_m=16)
    gb, rb = gram_rhs(u, mat, block_m=bm)
    np.testing.assert_allclose(gb, g16, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(rb, r16, rtol=1e-5, atol=1e-5)


def test_shrink_properties():
    """Shrinkage kills sub-threshold entries and biases the rest by λ."""
    m, n_i, r = 8, 8, 2
    u = jnp.zeros((m, r), dtype=jnp.float32)
    v = jnp.zeros((n_i, r), dtype=jnp.float32)
    mat = jnp.array(np.linspace(-3, 3, m * n_i).reshape(m, n_i), dtype=jnp.float32)
    s = residual_shrink(u, v, mat, 1.0, block_m=8)
    expected = np.sign(mat) * np.maximum(np.abs(mat) - 1.0, 0.0)
    np.testing.assert_allclose(s, expected, atol=1e-6)


def test_ridge_solve_ref_satisfies_normal_equations():
    g = jnp.array([[2.0, 0.3], [0.3, 1.5]], dtype=jnp.float32)
    rhs = rand(5, (2, 7))
    rho = 0.1
    v = ridge_solve_ref(g, rhs, rho)
    lhs = (g + rho * jnp.eye(2)) @ v.T
    np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)
